"""AnalyticsService: the fault-tolerant concurrent query-serving facade.

    service = AnalyticsService(ServiceConfig(...))
    service.start()                             # background drain loop
    rid = service.submit(plan, tables, priority=2)   # None => backpressured
    res = service.result(rid, timeout=5.0)      # or service.drain()
    service.stats()                             # ServiceStats snapshot
    service.stop(); service.close()

``submit`` is non-blocking admission into the bounded priority queue.
Serving runs in one of two modes:

  * **submit-then-drain** (the original mode): ``drain()`` pulls batches
    until the entry backlog is served;
  * **always-on** (``start()``): a background drain thread serves rounds
    continuously — admission happens DURING service — with an adaptive
    batching window (grow ``max_batch`` under backlog for QPS, shrink
    when idle for p99; see batcher.AdaptiveBatchWindow).

Each round groups requests by plan-cache key (batcher), dispatches one
task per distinct (plan, context, signature, tables) through the morsel
scheduler's socket-pinned pools, and fans shared results out. Failed or
hung dispatches are retried under ``ServiceConfig.retry`` (exponential
backoff, deterministic jitter, per-request deadline respected across
attempts); the scheduler's heartbeat/EWMA sweep quarantines dead or
straggling pools between wait ticks and requeues their backlog, so the
service keeps serving on a shrunk pool set. Results stay bit-identical
to serial execution because whole-plan dispatch is idempotent and morsel
partials merge in morsel order regardless of which pool ran them — on
the split-probe path (scheduler._probe_split_decompose: join probe
morsels over pool-replicated build sides) the merge is a morsel-order
row CONCATENATION feeding one finalize, so no reduction is ever
reassociated and re-dispatch after a fault reproduces the serial answer
bit-for-bit.

Every admitted request gets EXACTLY ONE terminal ``QueryResult``: a
value, ``expired`` (deadline passed — at dequeue, between rounds, or
mid-flight), ``shed`` (evicted lowest-priority-first under overload), or
an exhausted-retries error. Per-class SLO attainment (deadline-met
fraction, retries, shed counts) is reported in ``ServiceStats.per_class``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.analytics import telemetry
from repro.analytics import tracing
from repro.analytics.plan import LogicalPlan
from repro.analytics.planner import ExecutionContext
from repro.analytics.service.batcher import AdaptiveBatchWindow, QueryBatcher
from repro.analytics.service.faults import ServiceFaultInjector
from repro.analytics.service.queue import AdmissionQueue, QueryRequest
from repro.analytics.service.retry import RetryPolicy
from repro.analytics.service.scheduler import (MorselScheduler,
                                               ThreadPlacement,
                                               WorkerLeakError)


@dataclass(frozen=True)
class ServiceConfig:
    n_pools: int = 2
    workers_per_pool: int = 2
    queue_depth: int = 256
    max_batch: int = 64            # requests pulled per drain round (cap)
    min_batch: int = 1             # adaptive-window floor (serve loop)
    morsel_rows: Optional[int] = None   # None = whole-plan (bit-identical)
    placement: ThreadPlacement = ThreadPlacement.OS_DEFAULT
    batching: bool = True
    steal: bool = True
    # -- graceful degradation ------------------------------------------------
    # depth at which offers start evicting lower-priority queued requests
    # (None = plain backpressure only, the pre-fault-tolerance behavior)
    shed_watermark: Optional[int] = None
    client_weights: Optional[Mapping[int, int]] = None
    # -- fault tolerance -----------------------------------------------------
    retry: Optional[RetryPolicy] = RetryPolicy()
    faults: Optional[ServiceFaultInjector] = None
    hang_timeout_s: Optional[float] = 60.0  # per-attempt wait budget
    wait_tick_s: float = 0.05      # heartbeat-check cadence while waiting
    straggler_threshold: float = 4.0
    straggler_warmup: int = 3
    hang_after_s: float = 30.0     # stale-heartbeat quarantine threshold
    idle_wait_s: float = 0.02      # serve-loop sleep when the queue is dry
    close_timeout_s: float = 5.0   # per-worker join budget in close()
    # latency/queue-wait histograms keep the most recent N samples: a
    # long-lived service must stay memory-bounded, and the percentiles
    # should reflect CURRENT tail behavior, not be diluted by hours of
    # old samples
    histogram_window: int = 8192


@dataclass
class QueryResult:
    req_id: int
    value: Optional[Dict[str, Any]]     # None => expired/shed/failed
    queue_wait_s: float = 0.0
    latency_s: float = 0.0
    batch_size: int = 1                 # requests served by this dispatch
    expired: bool = False               # deadline passed before a value
    shed: bool = False                  # evicted under overload
    attempts: int = 1                   # dispatch attempts consumed
    priority: int = 1
    error: Optional[str] = None         # terminal failure, per dispatch
    # latency attribution for completed requests: seconds per phase
    # (queue_wait / batch_wait / retry_backoff / execute / merge), built
    # from DISJOINT sub-intervals of [submit_t, done_t] so the sum can
    # never exceed latency_s; None for expired/shed/failed terminals
    phases: Optional[Dict[str, float]] = None


def _pct(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    return float(np.percentile(np.asarray(sorted_vals), q))


# latency-attribution phase names, in serving-path order
PHASES = ("queue_wait", "batch_wait", "retry_backoff", "execute", "merge")


def _phase_pcts(samples: List[Dict[str, float]],
                q: float) -> Dict[str, float]:
    """Per-phase percentile (ms) over a window of phase dicts."""
    out: Dict[str, float] = {}
    for name in PHASES:
        vals = [p[name] for p in samples if name in p]
        out[name] = _pct(vals, q) * 1e3 if vals else 0.0
    return out


@dataclass
class ClassStats:
    """Per-priority-class outcome counters + SLO attainment."""

    priority: int
    admitted: int = 0
    rejected: int = 0
    completed: int = 0
    failed: int = 0
    expired: int = 0
    shed: int = 0
    retries: int = 0
    deadline_total: int = 0        # terminal requests that HAD a deadline
    deadline_met: int = 0          # ... that got a value within it
    # latency attribution (ms): phase -> percentile over this class's
    # completed requests, decomposing the end-to-end percentile into
    # queue_wait / batch_wait / retry_backoff / execute / merge
    phase_p50_ms: Dict[str, float] = field(default_factory=dict)
    phase_p95_ms: Dict[str, float] = field(default_factory=dict)
    phase_p99_ms: Dict[str, float] = field(default_factory=dict)

    @property
    def slo_attainment(self) -> float:
        """Deadline-met fraction over requests that carried a deadline
        (1.0 when none did — nothing promised, nothing missed)."""
        if self.deadline_total == 0:
            return 1.0
        return self.deadline_met / self.deadline_total


@dataclass
class ServiceStats:
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    expired: int = 0
    shed: int = 0                  # overload-shed (lowest-priority-first)
    failed: int = 0
    completed: int = 0
    retries: int = 0               # extra dispatch attempts
    requeued: int = 0              # morsels moved off dead/straggler pools
    batches: int = 0
    dispatches: int = 0
    dedup_hits: int = 0
    morsels: int = 0
    steals: int = 0
    steals_per_pool: Tuple[int, ...] = ()
    dead_pools: Tuple[int, ...] = ()
    quarantined_pools: Tuple[int, ...] = ()
    batch_window: int = 0          # adaptive window (serve-loop mode)
    per_class: Dict[int, ClassStats] = field(default_factory=dict)
    qps: float = 0.0
    latency_p50_ms: float = 0.0
    latency_p95_ms: float = 0.0
    latency_p99_ms: float = 0.0
    queue_wait_p50_ms: float = 0.0
    queue_wait_p95_ms: float = 0.0
    queue_wait_p99_ms: float = 0.0
    # fleet-wide latency attribution (ms): where the pXX actually goes
    phase_p50_ms: Dict[str, float] = field(default_factory=dict)
    phase_p95_ms: Dict[str, float] = field(default_factory=dict)
    phase_p99_ms: Dict[str, float] = field(default_factory=dict)
    # execution-telemetry snapshot (the process-global StatsRegistry at
    # stats() time — all zero unless telemetry is enabled): plans with
    # recorded stats, recorded executions, plans currently outside the
    # drift band, and adaptive replans the planner performed on cache hits
    plans_tracked: int = 0
    telemetry_executions: int = 0
    drifting_plans: int = 0
    replans: int = 0

    def describe(self) -> str:
        return (f"completed={self.completed}/{self.submitted} "
                f"(rejected={self.rejected}, expired={self.expired}, "
                f"shed={self.shed}, failed={self.failed}) "
                f"dispatches={self.dispatches} dedup={self.dedup_hits} "
                f"retries={self.retries} requeued={self.requeued} "
                f"steals={self.steals} qps={self.qps:.1f} "
                f"p50={self.latency_p50_ms:.2f}ms "
                f"p99={self.latency_p99_ms:.2f}ms")


def _new_class_counts() -> Dict[str, int]:
    return {"completed": 0, "failed": 0, "expired_late": 0, "retries": 0,
            "deadline_total": 0, "deadline_met": 0}


class AnalyticsService:
    """Queue -> batcher -> scheduler -> pools, with retries + histograms."""

    def __init__(self, config: Optional[ServiceConfig] = None):
        self.config = config or ServiceConfig()
        self.queue = AdmissionQueue(
            self.config.queue_depth,
            shed_watermark=self.config.shed_watermark,
            client_weights=self.config.client_weights)
        self.batcher = QueryBatcher()
        self.scheduler = MorselScheduler(
            n_pools=self.config.n_pools,
            workers_per_pool=self.config.workers_per_pool,
            placement=self.config.placement,
            morsel_rows=self.config.morsel_rows,
            steal=self.config.steal,
            faults=self.config.faults,
            straggler_threshold=self.config.straggler_threshold,
            straggler_warmup=self.config.straggler_warmup,
            hang_after_s=self.config.hang_after_s)
        self._lock = threading.Lock()
        self._next_id = 0
        window = self.config.histogram_window
        self._latencies: "deque[float]" = deque(maxlen=window)
        self._waits: "deque[float]" = deque(maxlen=window)
        # latency-attribution windows: phase dicts for completed requests,
        # fleet-wide and per class (same bounded-window discipline)
        self._phases: "deque[Dict[str, float]]" = deque(maxlen=window)
        self._class_phases: Dict[int, deque] = {}
        self._completed = 0
        self._failed = 0
        self._expired_late = 0     # expired after dequeue (not queue-counted)
        self._retries = 0
        self._dispatches = 0       # tasks successfully submitted
        self._dedup_hits = 0       # requests served by a peer's dispatch
        self._classes: Dict[int, Dict[str, int]] = {}
        self._busy_s = 0.0         # union of active-serving time (no idle)
        self._active_drains = 0
        self._busy_start = 0.0
        # terminal results + pending-request tracking (always maintained;
        # the serve loop writes here, drain()/result() read)
        self._results: Dict[int, QueryResult] = {}
        self._pending: set = set()
        self._results_cv = threading.Condition(self._lock)
        self._window = self.config.max_batch
        # serve-loop lifecycle
        self._serve_thread: Optional[threading.Thread] = None
        self._stop_flag = False
        self._drain_on_stop = True
        self._wake = threading.Condition()

    # -- client side --------------------------------------------------------
    def submit(self, plan: LogicalPlan,
               tables: Mapping[str, Mapping[str, Any]], *,
               context: Optional[ExecutionContext] = None,
               deadline_s: Optional[float] = None,
               client_id: int = 0, priority: int = 1) -> Optional[int]:
        """Admit one query. Returns the request id, or None when the queue
        is full (backpressure — the caller decides whether to retry).
        ``deadline_s`` is RELATIVE seconds from now; ``priority`` is the
        service class (higher = dequeued first, shed last)."""
        with self._lock:
            rid = self._next_id
            self._next_id += 1
        req = QueryRequest(
            req_id=rid, plan=plan, tables=tables,
            context=context or ExecutionContext(),
            deadline_s=(None if deadline_s is None
                        else time.monotonic() + deadline_s),
            client_id=client_id, priority=priority)
        if not self.queue.offer(req):
            return None
        with self._lock:
            self._pending.add(rid)
        # the offer may have evicted a lower-priority victim: give it its
        # terminal result immediately (the serve loop would also collect
        # it, but submit-then-drain mode must not leave it pending)
        self._collect_overload_shed(None)
        with self._wake:
            self._wake.notify_all()
        return rid

    def result(self, req_id: int,
               timeout: Optional[float] = None) -> Optional[QueryResult]:
        """Pop the terminal result for one request, waiting up to
        ``timeout`` seconds (None = forever). Returns None on timeout."""
        end = None if timeout is None else time.monotonic() + timeout
        with self._results_cv:
            while req_id not in self._results:
                remaining = None if end is None else end - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return None
                self._results_cv.wait(0.05 if remaining is None
                                      else min(0.05, remaining))
            return self._results.pop(req_id)

    def take_results(self) -> Dict[int, QueryResult]:
        """Pop every terminal result recorded so far."""
        with self._lock:
            out, self._results = self._results, {}
            return out

    # -- always-on serving --------------------------------------------------
    def start(self) -> "AnalyticsService":
        """Start the background drain loop: admission during service,
        adaptive batching window, continuous pool health checks."""
        with self._lock:
            if self._serve_thread is not None:
                return self
            self._stop_flag = False
            t = threading.Thread(target=self._serve_loop,
                                 name="svc-drain-loop", daemon=True)
            self._serve_thread = t
        t.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the background loop. ``drain=True`` (default) serves the
        remaining backlog first so no admitted request is left pending."""
        with self._lock:
            t = self._serve_thread
        if t is None:
            return
        with self._wake:
            self._stop_flag = True
            self._drain_on_stop = drain
            self._wake.notify_all()
        t.join()
        with self._lock:
            self._serve_thread = None
            self._stop_flag = False

    @property
    def serving(self) -> bool:
        with self._lock:
            return self._serve_thread is not None

    def _serve_loop(self) -> None:
        window = AdaptiveBatchWindow(self.config.min_batch,
                                     self.config.max_batch)
        while True:
            self._collect_overload_shed(None)
            # deadline staleness: shed requests that expired while earlier
            # rounds were served, instead of dequeuing them late
            for req in self.queue.shed_expired():
                self._record(req, expired=True, out=None)
            reqs, shed = self.queue.take_batch(window.window)
            for req in shed:
                self._record(req, expired=True, out=None)
            if reqs:
                self._busy_enter()
                try:
                    self._serve_round(reqs, None)
                finally:
                    self._busy_exit()
                with self._lock:
                    self._window = window.observe(len(self.queue))
                self.scheduler.check_pools()
                continue
            self.scheduler.check_pools()
            with self._lock:
                self._window = window.observe(0)
            with self._wake:
                if self._stop_flag:
                    if self._drain_on_stop and len(self.queue) > 0:
                        continue
                    return
                if len(self.queue) == 0:
                    self._wake.wait(self.config.idle_wait_s)

    # -- submit-then-drain serving ------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> Dict[int, QueryResult]:
        """Serve everything queued AT ENTRY; returns per-request results.

        With the background loop running this instead WAITS until every
        admitted request has a terminal result (up to ``timeout``) and
        returns all results accumulated so far.

        Pull-based mode: each round takes up to ``max_batch`` requests,
        batches them, dispatches every (batch, tables-identity) group as
        one task, and waits for the round before pulling the next —
        queue-wait for later requests therefore includes earlier rounds'
        service time, exactly the open-loop backlog the p99 histogram
        should see. The backlog is SNAPSHOTTED at entry: requests
        admitted while this call is serving wait for the next drain, so a
        submitter keeping pace with the service can never pin drain() in
        an unbounded loop. Deadlines are re-checked after every round, so
        a request that expires while an earlier round is being served is
        shed (counted in ``expired``) instead of dispatched late."""
        if self.serving:
            end = None if timeout is None else time.monotonic() + timeout
            with self._results_cv:
                while self._pending:
                    if end is not None and time.monotonic() >= end:
                        break
                    self._results_cv.wait(0.05)
            return self.take_results()
        out: Dict[int, QueryResult] = {}
        self._busy_enter()
        try:
            self._drain_snapshot(out)
        finally:
            self._busy_exit()
        out.update(self.take_results())
        return out

    def _busy_enter(self) -> None:
        t = time.monotonic()
        with self._lock:
            if self._active_drains == 0:
                self._busy_start = t
            self._active_drains += 1

    def _busy_exit(self) -> None:
        with self._lock:
            self._active_drains -= 1
            if self._active_drains == 0:
                # busy time is the UNION of active-serving intervals:
                # overlapping drains must not double-count (qps would
                # be understated)
                self._busy_s += time.monotonic() - self._busy_start

    def _drain_snapshot(self, out: Dict[int, QueryResult]) -> None:
        remaining = len(self.queue)
        while remaining > 0:
            round_reqs, shed = self.queue.take_batch(
                min(self.config.max_batch, remaining))
            remaining -= len(round_reqs) + len(shed)
            for req in shed:
                self._record(req, expired=True, out=out)
            if not round_reqs:
                if shed:
                    continue        # whole round expired; keep draining
                break
            self._serve_round(round_reqs, out)
            # deadline staleness fix: requests that expired while THIS
            # round was being served are shed now, not dispatched late by
            # a later round
            for req in self.queue.shed_expired():
                remaining -= 1
                self._record(req, expired=True, out=out)
            for req in self.queue.pop_overload_shed():
                remaining -= 1
                self._record(req, shed=True, out=out)

    # -- one serving round --------------------------------------------------
    def _serve_round(self, round_reqs: List[QueryRequest],
                     out: Optional[Dict[int, QueryResult]]) -> None:
        # dispatch-time deadline re-check: take_batch's check can go stale
        # while the batch waits its turn behind other rounds
        now = time.monotonic()
        live = []
        for req in round_reqs:
            if req.expired(now):
                self._record(req, expired=True, late_expired=True, out=out)
            else:
                live.append(req)
        if not live:
            return
        if self.config.batching:
            batches = self.batcher.group(live)
            shares = [s for b in batches for s in b.shares]
        else:
            shares = [[r] for r in live]
        inflight = []
        for share in shares:
            # build/submit can raise eagerly (e.g. a plan naming a table
            # its mapping lacks, caught at morsel decompose, or an
            # injected build fault): that failure belongs to THIS share
            # only, never to the round's other requests — and is retried
            # under the policy before going terminal
            task, attempt, err, build_start, backoff = \
                self._dispatch_share(share)
            if task is None:
                self._fan_out(share, None, err, attempt, out)
            else:
                with self._lock:
                    # dedup counted once per share, at its FIRST
                    # successful submit — a share that never dispatched
                    # deduped nothing
                    self._dedup_hits += len(share) - 1
                inflight.append((task, share, attempt, build_start,
                                 backoff))
        for task, share, attempt, build_start, backoff in inflight:
            # fault isolation: one failing dispatch must not discard the
            # round's other results or poison co-submitted clients
            self._await_share(task, share, attempt, out, build_start,
                              backoff)

    def _share_deadline(self, share: List[QueryRequest]) -> Optional[float]:
        """The share keeps trying while ANY member can still benefit."""
        if any(r.deadline_s is None for r in share):
            return None
        return max(r.deadline_s for r in share)

    def _can_retry(self, attempt: int, deadline: Optional[float],
                   rep: QueryRequest) -> bool:
        policy = self.config.retry
        return (policy is not None
                and policy.should_retry(attempt, time.monotonic(),
                                        deadline, key=rep.req_id))

    def _count_retry(self, rep: QueryRequest) -> None:
        with self._lock:
            self._retries += 1
            self._class_counts(rep.priority)["retries"] += 1

    def _try_dispatch(self, rep: QueryRequest):
        """One build+submit attempt -> (task, None) | (None, error str)."""
        traced = tracing.tracing_enabled()
        t0 = time.monotonic() if traced else 0.0
        try:
            task = self.scheduler.build_task(rep.plan, rep.tables,
                                             rep.context)
            # thread the request id through the scheduler BEFORE submit:
            # morsel.run / steal / merge spans attribute to this request
            task.trace_id = rep.req_id
            self.scheduler.submit(task)
        except Exception as e:  # noqa: BLE001 — reported per share
            if traced:
                tracing.tracer().add_complete(
                    "dispatch.build", "service", t0, time.monotonic(),
                    trace_id=rep.req_id, error=type(e).__name__)
            return None, f"{type(e).__name__}: {e}"
        if traced:
            tracing.tracer().add_complete(
                "dispatch.build", "service", t0, time.monotonic(),
                trace_id=rep.req_id, morsels=len(task.morsels))
        with self._lock:
            self._dispatches += 1
        return task, None

    def _backoff(self, attempt: int, rep: QueryRequest) -> float:
        """Sleep the retry backoff; returns the slept seconds (the
        retry_backoff attribution phase) and records the span."""
        delay = self.config.retry.backoff_s(attempt, key=rep.req_id)
        if tracing.tracing_enabled():
            t0 = time.monotonic()
            time.sleep(delay)
            tracing.tracer().add_complete(
                "retry.backoff", "service", t0, time.monotonic(),
                trace_id=rep.req_id, attempt=attempt)
        else:
            time.sleep(delay)
        return delay

    def _dispatch_share(self, share: List[QueryRequest]):
        """Build+submit with retry/backoff.

        Returns (task|None, attempts, err, build_start, backoff_s):
        ``build_start`` is the monotonic stamp at which THIS share's
        first build attempt began (the end of its batch-wait phase) and
        ``backoff_s`` the backoff slept so far — both feed latency
        attribution."""
        rep = share[0]
        deadline = self._share_deadline(share)
        build_start = time.monotonic()
        backoff = 0.0
        attempt = 0
        while True:
            attempt += 1
            task, err = self._try_dispatch(rep)
            if task is not None:
                return task, attempt, None, build_start, backoff
            if not self._can_retry(attempt, deadline, rep):
                return None, attempt, err, build_start, backoff
            self._count_retry(rep)
            backoff += self._backoff(attempt, rep)

    def _await_share(self, task, share: List[QueryRequest], attempt: int,
                     out: Optional[Dict[int, QueryResult]],
                     build_start: float = 0.0,
                     backoff: float = 0.0) -> None:
        """Wait for a dispatched share; retry failed/hung dispatches under
        the policy (per-request deadline respected across attempts)."""
        rep = share[0]
        deadline = self._share_deadline(share)
        while True:
            error = None
            if task is not None:
                value, error, deadline_hit = self._await_task(task, deadline)
                if error is None:
                    self._fan_out(share, task, None, attempt, out,
                                  value=value, build_start=build_start,
                                  backoff=backoff)
                    return
                if deadline_hit:
                    # every member's deadline passed mid-flight (the share
                    # deadline is the max): expired, not failed
                    for req in share:
                        self._record(req, expired=True, late_expired=True,
                                     attempts=attempt,
                                     batch_size=len(share), out=out)
                    return
            if not self._can_retry(attempt, deadline, rep):
                self._fan_out(share, task, error, attempt, out)
                return
            self._count_retry(rep)
            backoff += self._backoff(attempt, rep)
            attempt += 1
            # re-dispatch: whole-plan tasks are idempotent (same compiled
            # executable, same inputs) and morsel partials merge in morsel
            # order — a retried dispatch returns the same result the
            # failed one would have
            task, error = self._try_dispatch(rep)

    def _await_task(self, task, deadline: Optional[float]):
        """Tick-wait on a task, sweeping pool health between ticks.

        Returns (value, None, False) on success; (None, err, False) on a
        retryable failure (exception or hang-budget timeout); (None, err,
        True) when the share's deadline passed while waiting."""
        start = time.monotonic()
        hang = self.config.hang_timeout_s
        while True:
            try:
                return task.wait(timeout=self.config.wait_tick_s), None, False
            except TimeoutError:
                # the tick path is where dead/straggler pools get noticed:
                # quarantine + requeue lets the SAME task finish on
                # surviving pools without burning a retry attempt
                self.scheduler.check_pools()
                now = time.monotonic()
                if deadline is not None and now > deadline:
                    return None, "deadline exceeded in flight", True
                if hang is not None and now - start > hang:
                    return (None, f"TimeoutError: dispatch exceeded "
                            f"hang budget {hang}s", False)
            except Exception as e:  # noqa: BLE001 — retried, then reported
                return None, f"{type(e).__name__}: {e}", False

    # -- terminal-result recording ------------------------------------------
    def _fan_out(self, share: List[QueryRequest], task, error: Optional[str],
                 attempts: int, out: Optional[Dict[int, QueryResult]],
                 value=None, build_start: float = 0.0,
                 backoff: float = 0.0) -> None:
        # latency uses the task's own completion stamp, not this loop's
        # join order (a fast query must not inherit a slow peer's
        # wait-loop position)
        done = (task.done_t if task is not None and task.done_t
                else time.monotonic())
        for req in share:
            phases = None
            if error is None and value is not None and task is not None \
                    and build_start and task.submit_t:
                # disjoint sub-intervals of [submit_t, done_t], so the sum
                # can never exceed the end-to-end wall:
                #   [submit, dequeue] [dequeue, build] (backoff sleeps)
                #   [sched submit, last morsel] [last morsel, merged]
                phases = {
                    "queue_wait": max(0.0, req.dispatch_t - req.submit_t)
                                  if req.dispatch_t else 0.0,
                    "batch_wait": max(0.0, build_start - req.dispatch_t)
                                  if req.dispatch_t else 0.0,
                    "retry_backoff": backoff,
                    "execute": max(0.0, task.merge_t - task.submit_t),
                    "merge": max(0.0, task.done_t - task.merge_t),
                }
            self._record(req, value=value, error=error, attempts=attempts,
                         batch_size=len(share), done=done, out=out,
                         phases=phases)

    def _class_counts(self, priority: int) -> Dict[str, int]:
        return self._classes.setdefault(priority, _new_class_counts())

    def _collect_overload_shed(
            self, out: Optional[Dict[int, QueryResult]]) -> None:
        for req in self.queue.pop_overload_shed():
            self._record(req, shed=True, out=out)

    def _record(self, req: QueryRequest, *, value=None,
                error: Optional[str] = None, expired: bool = False,
                shed: bool = False, late_expired: bool = False,
                attempts: int = 1, batch_size: int = 1,
                done: Optional[float] = None,
                out: Optional[Dict[int, QueryResult]] = None,
                phases: Optional[Dict[str, float]] = None) -> None:
        """The single terminal-result sink: stats, SLO, result store."""
        traced = tracing.tracing_enabled()
        done = time.monotonic() if done is None else done
        wait = ((req.dispatch_t if req.dispatch_t else done) - req.submit_t)
        res = QueryResult(
            req_id=req.req_id,
            # shallow-copy per client: deduplicated peers must not see
            # each other's in-place edits (the arrays inside are
            # immutable and stay shared)
            value=dict(value) if value is not None else None,
            queue_wait_s=max(0.0, wait),
            latency_s=max(0.0, done - req.submit_t),
            batch_size=batch_size, expired=expired, shed=shed,
            attempts=attempts, priority=req.priority, error=error,
            phases=phases)
        if traced:
            if shed:
                # graceful degradation tripped: leave a postmortem
                tracing.tracer().flight_dump(
                    "overload.shed", req=req.req_id, cls=req.priority)
            # delivery lag: task completion -> terminal result visible
            tracing.tracer().add_complete(
                "result.deliver", "service", done, time.monotonic(),
                trace_id=req.req_id,
                outcome=("error" if error is not None else
                         "expired" if expired else
                         "shed" if shed else "ok"))
        with self._lock:
            cls = self._class_counts(req.priority)
            if error is not None:
                self._failed += 1
                cls["failed"] += 1
            elif expired:
                if late_expired:
                    # queue-side sheds were already counted by the queue;
                    # post-dequeue expiries are ours to count
                    self._expired_late += 1
                    cls["expired_late"] += 1
            elif not shed:
                self._completed += 1
                cls["completed"] += 1
                self._latencies.append(res.latency_s)
                self._waits.append(res.queue_wait_s)
                if phases is not None:
                    self._phases.append(phases)
                    pw = self._class_phases.get(req.priority)
                    if pw is None:
                        pw = self._class_phases[req.priority] = deque(
                            maxlen=self.config.histogram_window)
                    pw.append(phases)
            if req.deadline_s is not None:
                cls["deadline_total"] += 1
                if error is None and not expired and not shed \
                        and done <= req.deadline_s:
                    cls["deadline_met"] += 1
            self._pending.discard(req.req_id)
            if out is None:
                self._results[req.req_id] = res
            self._results_cv.notify_all()
        if out is not None:
            out[req.req_id] = res

    # -- stats --------------------------------------------------------------
    def stats(self) -> ServiceStats:
        qs = self.queue.stats()
        bs = self.batcher.stats()
        ss = self.scheduler.stats()
        tsum = telemetry.registry().summary()
        with self._lock:
            lat = list(self._latencies)
            waits = list(self._waits)
            completed = self._completed
            failed = self._failed
            expired_late = self._expired_late
            retries = self._retries
            dispatches = self._dispatches
            dedup_hits = self._dedup_hits
            window = self._window
            classes = {p: dict(c) for p, c in self._classes.items()}
            phases = list(self._phases)
            class_phases = {p: list(w)
                            for p, w in self._class_phases.items()}
            busy = self._busy_s
            if self._active_drains > 0:   # include the in-progress round
                busy += time.monotonic() - self._busy_start
        per_class: Dict[int, ClassStats] = {}
        for p, c in qs.by_class.items():
            per_class[p] = ClassStats(
                priority=p, admitted=c["admitted"], rejected=c["rejected"],
                expired=c["expired"], shed=c["shed"])
        for p, c in classes.items():
            cs = per_class.setdefault(p, ClassStats(priority=p))
            cs.completed = c["completed"]
            cs.failed = c["failed"]
            cs.expired += c["expired_late"]
            cs.retries = c["retries"]
            cs.deadline_total = c["deadline_total"]
            cs.deadline_met = c["deadline_met"]
        for p, w in class_phases.items():
            cs = per_class.setdefault(p, ClassStats(priority=p))
            cs.phase_p50_ms = _phase_pcts(w, 50)
            cs.phase_p95_ms = _phase_pcts(w, 95)
            cs.phase_p99_ms = _phase_pcts(w, 99)
        return ServiceStats(
            submitted=qs.submitted, admitted=qs.admitted,
            rejected=qs.rejected_full, expired=qs.expired + expired_late,
            shed=qs.shed_overload, failed=failed, completed=completed,
            retries=retries, requeued=ss.requeued, batches=bs.batches,
            dispatches=dispatches, dedup_hits=dedup_hits,
            morsels=ss.morsels_dispatched, steals=ss.steals,
            steals_per_pool=ss.steals_per_pool,
            dead_pools=ss.dead_pools,
            quarantined_pools=ss.quarantined_pools,
            batch_window=window, per_class=per_class,
            qps=(completed / busy) if busy > 0 else 0.0,
            latency_p50_ms=_pct(lat, 50) * 1e3,
            latency_p95_ms=_pct(lat, 95) * 1e3,
            latency_p99_ms=_pct(lat, 99) * 1e3,
            queue_wait_p50_ms=_pct(waits, 50) * 1e3,
            queue_wait_p95_ms=_pct(waits, 95) * 1e3,
            queue_wait_p99_ms=_pct(waits, 99) * 1e3,
            phase_p50_ms=_phase_pcts(phases, 50),
            phase_p95_ms=_phase_pcts(phases, 95),
            phase_p99_ms=_phase_pcts(phases, 99),
            plans_tracked=tsum["plans_tracked"],
            telemetry_executions=tsum["executions"],
            drifting_plans=tsum["drifting_plans"],
            replans=tsum["replans"])

    # -- tracing ------------------------------------------------------------
    def export_trace(self, path: str) -> None:
        """Write the tracer's current span window as Chrome trace-event
        JSON (open in perfetto or chrome://tracing). Spans exist only for
        rounds served under ``tracing.tracing()`` / ``enable_tracing``."""
        tracing.tracer().trace().save(path)

    def flight_dumps(self):
        """The flight recorder's postmortem ring (fault trips, sheds,
        quarantines, worker leaks) — newest last."""
        return tracing.tracer().flight.dumps()

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Stop serving and join every worker; a wedged pool raises
        WorkerLeakError instead of leaking daemon threads invisibly."""
        self.stop()
        unjoined = self.scheduler.close(timeout=self.config.close_timeout_s)
        if unjoined:
            if tracing.tracing_enabled():
                tracing.tracer().flight_dump("worker.leak",
                                             unjoined=list(unjoined))
            raise WorkerLeakError(unjoined)

    def __enter__(self) -> "AnalyticsService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
