"""Retry policy: bounded attempts, exponential backoff, deterministic jitter.

A failed dispatch (build raised, wait() poisoned, hang-budget timeout) is
retried up to ``max_attempts`` total attempts with exponentially growing
backoff. Jitter is DETERMINISTIC — a hash of (request key, attempt) — so
a replayed fault schedule produces a replayed retry schedule; real
deployments get the thundering-herd spread, tests get reproducibility.

The per-request deadline is respected ACROSS attempts: ``give_up_at``
caps the next backoff against the deadline, so a request never sleeps
through its own budget — it is reported expired/exhausted instead of
retried past the point a client stopped listening.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


def _hash_frac(key: int, attempt: int) -> float:
    """Deterministic uniform-ish fraction in [0, 1) from (key, attempt)."""
    h = (key * 2654435761 + attempt * 40503 + 0x9E3779B9) & 0xFFFFFFFF
    h ^= h >> 16
    h = (h * 0x45D9F3B) & 0xFFFFFFFF
    h ^= h >> 16
    return h / 2.0 ** 32


@dataclass(frozen=True)
class RetryPolicy:
    """``max_attempts`` counts the first try: 3 = one try + two retries."""

    max_attempts: int = 3
    base_backoff_s: float = 0.01
    multiplier: float = 2.0
    max_backoff_s: float = 1.0
    jitter: float = 0.5            # fraction of the backoff randomized away

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")

    def backoff_s(self, attempt: int, key: int = 0) -> float:
        """Sleep before attempt ``attempt+1`` (attempt is 1-based tries
        already made). Jitter subtracts up to ``jitter`` of the backoff —
        deterministic in (key, attempt)."""
        raw = min(self.max_backoff_s,
                  self.base_backoff_s * self.multiplier ** (attempt - 1))
        return raw * (1.0 - self.jitter * _hash_frac(key, attempt))

    def should_retry(self, attempt: int, now: float,
                     deadline_s: Optional[float], key: int = 0) -> bool:
        """True when another attempt is allowed AND its backoff fits the
        request's remaining deadline budget."""
        if attempt >= self.max_attempts:
            return False
        if deadline_s is None:
            return True
        return now + self.backoff_s(attempt, key) < deadline_s
