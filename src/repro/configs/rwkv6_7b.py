"""rwkv6-7b (Finch): attention-free, data-dependent decay
[arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b]."""
from repro.core.config import ArchConfig, AttentionKind, RWKVConfig

ARCH = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # d_model / head_size
    n_kv_heads=0,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attention=AttentionKind.NONE,
    rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32),
    source="arXiv:2404.05892 (Eagle & Finch); hf:RWKV/rwkv-6-world-7b",
)
