"""granite-3-8b: IBM Granite 3.0 dense GQA [hf:ibm-granite/granite-3.0-8b-base]."""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-8b-base (per assignment table)",
)
