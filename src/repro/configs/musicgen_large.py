"""musicgen-large: decoder-only transformer over EnCodec tokens, 4 parallel
codebook streams [arXiv:2306.05284; hf]. Modality frontend (EnCodec) is a
stub: input_specs supplies precomputed frame embeddings; the 4 codebook
heads + codebook embedding tables are real. Positional scheme adapted from
learned-sinusoidal to RoPE (documented deviation, DESIGN.md §8)."""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    act="gelu",
    rope_theta=10_000.0,
    source="arXiv:2306.05284 (MusicGen); hf:facebook/musicgen-large",
)
