"""recurrentgemma-2b: Griffin hybrid — RG-LRU + local attention, pattern
(recurrent, recurrent, local-attn) [arXiv:2402.19427; hf]."""
from repro.core.config import ArchConfig, AttentionKind, HybridConfig

ARCH = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attention=AttentionKind.HYBRID,
    hybrid=HybridConfig(pattern=("rglru", "rglru", "local_attn"),
                        window=2048, d_rnn=2560, conv_width=4),
    act="gelu",
    tie_embeddings=True,
    rope_theta=10_000.0,
    source="arXiv:2402.19427 (Griffin/RecurrentGemma); hf:google/recurrentgemma-2b",
)
