"""deepseek-v3-671b: MLA + 256-expert top-8 MoE (1 shared expert), 3 leading
dense layers, multi-token prediction head [arXiv:2412.19437]."""
from repro.core.config import ArchConfig, AttentionKind, MLAConfig, MoEConfig

ARCH = ArchConfig(
    name="deepseek-v3",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: per-head view; true cache is the 512-d latent
    head_dim=128,
    d_ff=2048,               # routed-expert FFN width (assignment table)
    vocab_size=129280,
    attention=AttentionKind.MLA,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048,
                  n_shared_experts=1, n_dense_layers=3, dense_d_ff=18432),
    mtp=True,
    rope_theta=10_000.0,
    source="arXiv:2412.19437 (DeepSeek-V3); hf:deepseek-ai/DeepSeek-V3",
)
