"""qwen2-vl-2b: VLM backbone with M-RoPE; vision frontend is a stub
(input_specs supplies precomputed patch embeddings + 3D positions)
[arXiv:2409.12191; hf]."""
from repro.core.config import ArchConfig, RopeKind

ARCH = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope=RopeKind.MROPE,
    rope_theta=1_000_000.0,
    vlm=True,
    n_patches=1024,
    source="arXiv:2409.12191 (Qwen2-VL); hf:Qwen/Qwen2-VL-2B",
)
