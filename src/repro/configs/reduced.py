"""Reduced same-family configs for CPU smoke tests.

Every assigned architecture gets a shrunken twin: same family, same block
structure (GQA ratios, MoE routing, hybrid pattern, MLA ranks scaled), tiny
widths — one forward/train step runs on CPU in seconds. The FULL configs are
exercised only through the dry-run (ShapeDtypeStruct lowering).
"""
from __future__ import annotations

import dataclasses

from repro.core.config import (ArchConfig, AttentionKind, HybridConfig,
                               MLAConfig, MoEConfig, RWKVConfig)
from repro.configs import ARCHS


def reduced(arch: ArchConfig) -> ArchConfig:
    kw = dict(
        n_layers=min(arch.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(arch.n_kv_heads, 2) if arch.n_kv_heads else 0,
        head_dim=16,
        d_ff=96,
        vocab_size=128,
        n_patches=8,
    )
    if arch.attention == AttentionKind.MLA:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
        kw["n_kv_heads"] = 4
    if arch.moe is not None:
        # capacity_factor 4.0: reduced configs route ~dozens of tokens, where
        # the production 1.25 factor would drop tokens and break exact
        # decode/forward parity
        kw["moe"] = dataclasses.replace(
            arch.moe, n_experts=4, top_k=2, d_expert=32,
            dense_d_ff=48 if arch.moe.dense_d_ff else None,
            capacity_factor=4.0)
    if arch.hybrid is not None:
        kw["hybrid"] = dataclasses.replace(arch.hybrid, window=8, d_rnn=64)
        kw["n_layers"] = 4  # (rglru, rglru, local_attn) + tail rglru
        kw["n_kv_heads"] = 1
    if arch.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_size=16, decay_lora=8, mix_lora=8)
        kw["n_heads"] = 4
        kw["head_dim"] = 16
    return dataclasses.replace(arch, **kw)


REDUCED = {name: reduced(a) for name, a in ARCHS.items()}
