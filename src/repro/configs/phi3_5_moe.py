"""phi3.5-moe-42b-a6.6b: 16-expert top-2 MoE with GQA
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.core.config import ArchConfig, MoEConfig

ARCH = ArchConfig(
    name="phi3.5-moe",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=6400),
    rope_theta=10_000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
