"""qwen2-0.5b: dense GQA with QKV bias, tied embeddings [arXiv:2407.10671; hf]."""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671 (Qwen2); hf:Qwen/Qwen2-0.5B",
)
