"""yi-34b: llama-architecture dense GQA [arXiv:2403.04652; hf]."""
from repro.core.config import ArchConfig

ARCH = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652 (Yi: Open Foundation Models); hf:01-ai/Yi-34B",
)
