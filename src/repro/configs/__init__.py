"""Assigned architecture registry: exact published dimensions.

Every config cites its source; padded (TP-divisible) dimensions are derived
at model-build time and recorded by the dry-run, never baked in here.
"""
from repro.configs.yi_34b import ARCH as YI_34B
from repro.configs.qwen2_0_5b import ARCH as QWEN2_0_5B
from repro.configs.qwen3_1_7b import ARCH as QWEN3_1_7B
from repro.configs.granite_3_8b import ARCH as GRANITE_3_8B
from repro.configs.recurrentgemma_2b import ARCH as RECURRENTGEMMA_2B
from repro.configs.musicgen_large import ARCH as MUSICGEN_LARGE
from repro.configs.phi3_5_moe import ARCH as PHI3_5_MOE
from repro.configs.deepseek_v3 import ARCH as DEEPSEEK_V3
from repro.configs.qwen2_vl_2b import ARCH as QWEN2_VL_2B
from repro.configs.rwkv6_7b import ARCH as RWKV6_7B

ARCHS = {
    a.name: a for a in (
        YI_34B, QWEN2_0_5B, QWEN3_1_7B, GRANITE_3_8B, RECURRENTGEMMA_2B,
        MUSICGEN_LARGE, PHI3_5_MOE, DEEPSEEK_V3, QWEN2_VL_2B, RWKV6_7B,
    )
}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
