from repro.checkpoint.checkpoint import (CheckpointManager, latest_step,
                                         restore, save)
