"""Sharded checkpointing: save/restore with atomic publish + async writes.

Layout:  <dir>/step_<n>.tmp/...  ->  rename  ->  <dir>/step_<n>/
  index.json          tree structure, shapes, dtypes
  <flat-key>.npy      one file per leaf (per-host shard in multi-host runs:
                      each host writes only its addressable shard and the
                      index records the global shape + host grid)
  COMMITTED           marker written last; restore ignores uncommitted dirs

Async: ``CheckpointManager.save_async`` snapshots to host RAM on the caller
thread (device->host copy), then writes on a background thread so the train
step resumes immediately — the standard overlap trick for large-model
checkpointing. Restore places leaves back with the provided shardings
(which may target a DIFFERENT mesh: elastic restarts reshard for free).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MARKER = "COMMITTED"


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
        if hasattr(tree, "_fields"):  # NamedTuple: record field names too
            pass
    elif tree is None:
        out[prefix.rstrip("/") + "@none"] = None
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_like(like: Any, flat: Dict[str, Any], prefix: str = "") -> Any:
    if isinstance(like, dict):
        return {k: _unflatten_like(like[k], flat, f"{prefix}{k}/")
                for k in sorted(like)}
    if isinstance(like, (list, tuple)):
        vals = [_unflatten_like(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(like)]
        return type(like)(*vals) if hasattr(like, "_fields") else \
            type(like)(vals)
    if like is None:
        return None
    return flat[prefix.rstrip("/")]


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    index = {}
    for key, val in flat.items():
        if key.endswith("@none"):
            index[key] = {"none": True}
            continue
        arr = np.asarray(val)
        stored_as = None
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # numpy cannot serialize bfloat16: store exactly as fp32
            arr = np.asarray(jnp.asarray(val).astype(jnp.float32))
            stored_as = "bfloat16"
        fname = key.replace("/", ".") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        index[key] = {"file": fname, "shape": list(arr.shape),
                      "dtype": stored_as or str(arr.dtype)}
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump({"step": step, "leaves": index}, f)
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MARKER)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Any:
    """Load a checkpoint into the structure of ``like`` (arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) re-places leaves —
    including onto a different mesh after elastic rescale."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, _MARKER)):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)["leaves"]
    flat = {}
    for key, meta in index.items():
        if meta.get("none"):
            continue
        arr = np.load(os.path.join(path, meta["file"]))
        if meta.get("dtype") == "bfloat16":
            arr = np.asarray(jnp.asarray(arr).astype(jnp.bfloat16))
        flat[key] = arr
    tree = _unflatten_like(like, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jnp.asarray(x),
            tree, shardings,
            is_leaf=lambda x: x is None or not isinstance(x, (dict, list, tuple)))
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree


class CheckpointManager:
    """Async checkpointing with bounded retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps = []

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def work():
            save(self.directory, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self.saved_steps.append(step)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
