"""Explicit data-parallel train step with compressed gradient all-reduce.

Under plain jit+shardings, XLA inserts the gradient all-reduce implicitly
and there is no seam to compress it. This step builds the seam: the
forward/backward runs inside shard_map (model replicated, batch sharded
over the data axes), gradients are synchronized EXPLICITLY — either a
plain pmean or the int8 block-quantized scheme with error feedback
(optim.compression) — and the optimizer update runs replicated on the
synced grads. 4× fewer gradient wire bytes than bf16 at ~1e-2 relative
gradient error (bounded by block max/127, test-checked), unbiased over
steps via the error-feedback carry.

This is the small-model/large-fleet regime's step (model fits per device);
the FSDP/TP steps in launch/dryrun cover the sharded-model regime.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.config import RunConfig
from repro.models.lm import LMModel
from repro.optim import adamw, schedules
from repro.optim.compression import compressed_psum


def make_dp_train_step(model: LMModel, cfg: RunConfig, mesh: Mesh, *,
                       axis: str = "data",
                       total_steps: int = 10_000) -> Callable:
    """Returns step(params, opt_state, errors, batch, step) ->
    (params, opt_state, errors, metrics). ``errors`` is the error-feedback
    pytree (zeros_like params fp32; ignored when compression is off)."""
    tcfg = cfg.train
    compress = cfg.sharding.gradient_compression

    def local_grads(params, batch):
        def loss_fn(p):
            loss, _ = model.loss_fn(p, batch, z_loss=tcfg.z_loss)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        return loss, grads

    def sharded_part(params, errors, batch):
        # per-device: local microbatch forward/backward
        loss, grads = local_grads(params, batch)
        loss = jax.lax.pmean(loss, axis)
        if compress:
            grads, errors = compressed_psum(grads, axis, errors)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
        return loss, grads, errors

    batch_spec = jax.tree.map(lambda _: P(axis), {"tokens": 0, "labels": 0})

    def step(params, opt_state, errors, batch, step_idx):
        wrapped = shard_map(
            sharded_part, mesh=mesh,
            in_specs=(P(), P(), {k: P(axis) for k in batch}),
            out_specs=(P(), P(), P()),
            check_rep=False)
        loss, grads, new_errors = wrapped(params, errors, batch)
        if not compress:
            # pmean already averaged; compression path averages internally
            pass
        lr = schedules.warmup_cosine(step_idx, peak_lr=tcfg.learning_rate,
                                     warmup_steps=tcfg.warmup_steps,
                                     total_steps=total_steps)
        new_params, new_opt, opt_metrics = adamw.update(
            grads, opt_state, params, lr, tcfg)
        metrics = {"loss": loss, "lr": lr, **opt_metrics}
        return new_params, new_opt, new_errors, metrics

    return step


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
