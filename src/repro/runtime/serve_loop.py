"""Serving runtime: continuous batching over a paged KV budget.

Wave-based continuous batching: a fixed device batch of ``wave_slots``
decode lanes; requests are admitted into free lanes whenever the paged KV
manager can reserve their pages (admission control = the allocator; the
THP/page-size knob directly moves fragmentation and admission latency).
Completed sequences release pages immediately, admitting queued work.

The device-side cache is wave-static (slots x max_len) while the manager
tracks logical pages — the admission/accounting split documented in
DESIGN.md. Throughput and fragmentation are the benchmark outputs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import AllocatorKind, RunConfig
from repro.memory.paged_kv import PagedKVManager
from repro.models.lm import LMModel


@dataclass
class Request:
    req_id: int
    prompt_len: int
    max_new_tokens: int
    generated: int = 0
    done: bool = False


@dataclass
class ServeStats:
    steps: int = 0
    tokens_out: int = 0
    admitted: int = 0
    completed: int = 0
    admission_stalls: int = 0
    lane_utilization: float = 0.0
    fragmentation: float = 0.0


class ContinuousBatcher:
    def __init__(self, model: LMModel, params, *, wave_slots: int,
                 max_len: int, page_tokens: int, n_pages: int,
                 allocator: AllocatorKind = AllocatorKind.SLAB,
                 kv_bytes_per_token: int = 2):
        self.model = model
        self.params = params
        self.wave_slots = wave_slots
        self.max_len = max_len
        self.kv = PagedKVManager(
            n_pages=n_pages, page_tokens=page_tokens,
            page_bytes=page_tokens * kv_bytes_per_token,
            allocator=allocator)
        self.lanes: List[Optional[Request]] = [None] * wave_slots
        self.queue: List[Request] = []
        self.cache = model.init_cache(wave_slots, max_len)
        self.stats = ServeStats()
        self._decode = jax.jit(model.decode_step)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.wave_slots):
            if self.lanes[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            self.kv.add_sequence(req.req_id)
            if not self.kv.append_tokens(req.req_id, req.prompt_len,
                                         stream=i):
                self.kv.release_sequence(req.req_id)
                self.stats.admission_stalls += 1
                return  # head-of-line blocked: wait for pages
            self.queue.pop(0)
            self.lanes[i] = req
            self.stats.admitted += 1

    def step(self) -> None:
        """One decode wave across all occupied lanes."""
        self._admit()
        occupied = [i for i, r in enumerate(self.lanes) if r is not None]
        if not occupied:
            return
        tokens = np.zeros((self.wave_slots, 1), np.int32)
        batch = ({"tokens": jnp.asarray(tokens)}
                 if not self.model.arch.n_codebooks else
                 {"codes": jnp.zeros(
                     (self.wave_slots, 1, self.model.arch.n_codebooks),
                     jnp.int32)})
        logits, self.cache = self._decode(self.params, self.cache, batch)
        self.stats.steps += 1
        self.stats.lane_utilization += len(occupied) / self.wave_slots
        for i in occupied:
            req = self.lanes[i]
            if not self.kv.append_tokens(req.req_id, 1, stream=i):
                # out of pages mid-flight: preempt (requeue) — the paper's
                # capacity-pressure case
                self.kv.release_sequence(req.req_id)
                self.queue.insert(0, dataclasses.replace(
                    req, generated=0))
                self.lanes[i] = None
                self.stats.admission_stalls += 1
                continue
            req.generated += 1
            self.stats.tokens_out += 1
            if req.generated >= req.max_new_tokens:
                req.done = True
                self.kv.release_sequence(req.req_id)
                self.lanes[i] = None
                self.stats.completed += 1
        # track PEAK fragmentation (end-state is trivially 0 after releases)
        self.stats.fragmentation = max(self.stats.fragmentation,
                                       self.kv.fragmentation_ratio())

    def run(self, max_steps: int = 1_000) -> ServeStats:
        for _ in range(max_steps):
            if not self.queue and all(l is None for l in self.lanes):
                break
            self.step()
        if self.stats.steps:
            self.stats.lane_utilization /= self.stats.steps
        return self.stats
