"""Fault tolerance: straggler detection, elastic re-meshing, failure drill.

Thread-placement instability is the paper's Figure 3: the OS default
produced order-of-magnitude step-time variance. At pod scale the same
pathology appears as stragglers (a slow host stretches every synchronous
step). The runtime therefore:

  * tracks per-host step times (EWMA) and flags hosts whose smoothed time
    exceeds ``threshold`` x the fleet median — mitigation is demotion
    (shrink the mesh without the slow host) or data-share rebalancing;
  * rebuilds the largest valid mesh from surviving devices on failure
    (elastic re-mesh) — checkpoint restore handles resharding because
    restore() takes target shardings;
  * provides a deterministic FailureInjector so the checkpoint/restart path
    is exercised in tests and examples, not just documented.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class SimulatedFailure(RuntimeError):
    """Raised by FailureInjector at scheduled steps."""


@dataclass
class FailureInjector:
    fail_at_steps: Sequence[int] = ()
    kill_hosts: int = 0            # hosts lost per failure (elastic drill)
    _fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step} "
                                   f"(-{self.kill_hosts} hosts)")


@dataclass
class StragglerReport:
    host: int
    ewma: float
    median: float
    ratio: float


class StragglerDetector:
    """EWMA per-host step times vs fleet median."""

    def __init__(self, n_hosts: int, alpha: float = 0.3,
                 threshold: float = 1.5, warmup: int = 3):
        self.n_hosts = n_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self._ewma = np.zeros(n_hosts)
        self._count = np.zeros(n_hosts, dtype=int)

    def record(self, host: int, seconds: float) -> None:
        if self._count[host] == 0:
            self._ewma[host] = seconds
        else:
            self._ewma[host] = (self.alpha * seconds
                                + (1 - self.alpha) * self._ewma[host])
        self._count[host] += 1

    def stragglers(self) -> List[StragglerReport]:
        ready = self._count >= self.warmup
        if ready.sum() < 2:
            return []
        med = float(np.median(self._ewma[ready]))
        out = []
        for h in range(self.n_hosts):
            if ready[h] and self._ewma[h] > self.threshold * med:
                out.append(StragglerReport(h, float(self._ewma[h]), med,
                                           float(self._ewma[h] / med)))
        return out

    def data_shares(self) -> np.ndarray:
        """Mitigation: per-host batch shares inversely proportional to the
        smoothed step time (slow hosts get less data; synchronous steps
        equalize). Normalized to sum to 1."""
        ready = self._count >= 1
        t = np.where(ready, np.maximum(self._ewma, 1e-9), 1.0)
        inv = 1.0 / t
        return inv / inv.sum()


def elastic_mesh_shape(n_devices: int, model_parallel: int
                       ) -> Tuple[int, int]:
    """Largest (data, model) grid that fits the surviving device count,
    keeping TP intact (model_parallel is fixed by the checkpointed layout;
    shrinking happens on the data axis — ZeRO/DP state reshards freely)."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with only "
            f"{n_devices} devices — TP degradation requires repartitioning")
    data = n_devices // model_parallel
    return data, model_parallel


def surviving_devices(devices: Sequence, n_lost: int) -> List:
    """Deterministically drop the last ``n_lost`` devices (drill stand-in
    for the real runtime's failed-host report)."""
    if n_lost <= 0:
        return list(devices)
    return list(devices)[:len(devices) - n_lost]
