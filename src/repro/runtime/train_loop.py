"""Training runtime: step builder + fault-tolerant loop.

``make_train_step`` builds the pure step function:
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
with gradient accumulation (lax.scan over microbatches — deepseek-scale
configs keep activation memory bounded this way) and optional int8
compressed data-parallel gradient sync.

``train`` is the driving loop: prefetched data, async checkpoints, step
timing, straggler tracking, and checkpoint/restart on (injected or real)
failures — the full FT cycle exercised by tests/examples on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import RunConfig
from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.data.pipeline import synth_batch
from repro.models.lm import LMModel
from repro.optim import adamw, schedules
from repro.runtime.ft import (FailureInjector, SimulatedFailure,
                              StragglerDetector)


def make_train_step(model: LMModel, cfg: RunConfig,
                    total_steps: int = 10_000) -> Callable:
    tcfg = cfg.train
    accum = tcfg.accum_steps

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch, z_loss=tcfg.z_loss)
        return loss, metrics

    def train_step(params, opt_state, batch, step):
        lr = schedules.warmup_cosine(step, peak_lr=tcfg.learning_rate,
                                     warmup_steps=tcfg.warmup_steps,
                                     total_steps=total_steps)
        if accum > 1:
            def micro(carry, mb):
                acc_grads, acc_loss = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_grads, acc_loss + loss), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            acc_dtype = jnp.dtype(tcfg.grad_accum_dtype)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                                params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zero, jnp.zeros((), jnp.float32)), micro_batches)
            grads = jax.tree.map(lambda g: (g / accum).astype(jnp.bfloat16),
                                 grads)
            loss = loss_sum / accum
            aux_metrics: Dict[str, jax.Array] = {}
        else:
            (loss, aux_metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        new_params, new_opt, opt_metrics = adamw.update(
            grads, opt_state, params, lr, tcfg)
        metrics = {"loss": loss, "lr": lr, **opt_metrics, **aux_metrics}
        return new_params, new_opt, metrics

    return train_step


@dataclasses.dataclass
class TrainResult:
    steps_run: int
    final_loss: float
    losses: list
    restarts: int
    straggler_events: int


def train(model: LMModel, cfg: RunConfig, *, n_steps: int,
          batch: int, seq: int, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 0, seed: int = 0,
          injector: Optional[FailureInjector] = None,
          param_dtype=jnp.float32) -> TrainResult:
    """CPU-runnable fault-tolerant training loop (reduced configs)."""
    from repro.core.params import init_params

    step_fn = jax.jit(make_train_step(model, cfg, total_steps=n_steps))
    mgr = CheckpointManager(ckpt_dir) if (ckpt_dir and ckpt_every) else None
    detector = StragglerDetector(n_hosts=1)

    def fresh_state():
        params = init_params(model.schema(), jax.random.PRNGKey(seed),
                             param_dtype)
        return params, adamw.init(params, cfg.train)

    params, opt_state = fresh_state()
    start = 0
    if mgr is not None:
        last = latest_step(ckpt_dir)
        if last is not None:
            params, opt_state = restore(
                ckpt_dir, last, (params, opt_state))
            start = last

    losses, restarts, step = [], 0, start
    while step < n_steps:
        try:
            if injector is not None:
                injector.check(step)
            b = {k: jnp.asarray(v) for k, v in
                 synth_batch(model.arch, batch, seq, step=step,
                             seed=seed).items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(
                params, opt_state, b, jnp.asarray(step))
            loss = float(metrics["loss"])
            detector.record(0, time.perf_counter() - t0)
            losses.append(loss)
            step += 1
            if mgr is not None and step % ckpt_every == 0:
                mgr.save_async(step, (params, opt_state))
        except SimulatedFailure:
            restarts += 1
            if mgr is not None:
                mgr.wait()
                last = latest_step(ckpt_dir)
                if last is not None:
                    params, opt_state = restore(ckpt_dir, last,
                                                (params, opt_state))
                    step = last
                else:
                    params, opt_state = fresh_state()
                    step = 0
            else:
                params, opt_state = fresh_state()
                step = 0
    if mgr is not None:
        mgr.wait()
    return TrainResult(steps_run=step, final_loss=losses[-1] if losses else float("nan"),
                       losses=losses, restarts=restarts,
                       straggler_events=len(detector.stragglers()))
