"""Runtime: fault-tolerant training loop, continuous-batching serving,
explicit data-parallel step with compressed gradient sync."""
from repro.runtime.dp_step import init_error_feedback, make_dp_train_step
from repro.runtime.ft import (FailureInjector, SimulatedFailure,
                              StragglerDetector, elastic_mesh_shape)
from repro.runtime.serve_loop import ContinuousBatcher, Request, ServeStats
from repro.runtime.train_loop import TrainResult, make_train_step, train
