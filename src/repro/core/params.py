"""Parameter schema system: declare-then-materialize parameters.

Models build a nested dict *schema* of ``ParamDef`` leaves (pure shape math —
no device memory). The schema supports three materializations:

  * ``abstract_params``  -> ShapeDtypeStruct tree (dry-run lowering; this is
                            how 671B-parameter configs are lowered on a CPU
                            container without allocating anything)
  * ``init_params``      -> real arrays (smoke tests / examples, reduced dims)
  * ``axes_tree/shapes_tree`` -> logical-axes and shape trees consumed by
                            core.partitioning to derive PartitionSpecs

Keys are split deterministically by folding the hash of the parameter path
into the root key, so parameter values are stable under schema reordering.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | scaled | uniform
    scale: Optional[float] = None  # stddev; default 1/sqrt(fan_in) for scaled
    dtype: Optional[str] = None    # per-param dtype override

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"shape {self.shape} and axes {self.axes} rank mismatch")


def pdef(shape: Sequence[int], axes: Sequence[Optional[str]],
         init: str = "normal", scale: Optional[float] = None,
         dtype: Optional[str] = None) -> ParamDef:
    return ParamDef(tuple(int(s) for s in shape), tuple(axes), init, scale,
                    dtype)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _iter_items(schema: Dict[str, Any], prefix: str = ""):
    for k in sorted(schema):
        v = schema[k]
        path = f"{prefix}/{k}" if prefix else k
        if is_def(v):
            yield path, v
        elif isinstance(v, dict):
            yield from _iter_items(v, path)
        else:
            raise TypeError(f"schema leaf {path} has type {type(v)}")


def _path_key(root: jax.Array, path: str) -> jax.Array:
    digest = hashlib.sha256(path.encode()).digest()
    return jax.random.fold_in(root, int.from_bytes(digest[:4], "little"))


def _materialize(d: ParamDef, key: jax.Array, dtype: Any) -> jax.Array:
    out_dtype = jnp.dtype(d.dtype or dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, out_dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, out_dtype)
    if d.init == "uniform":
        scale = d.scale if d.scale is not None else 1.0
        return jax.random.uniform(key, d.shape, jnp.float32,
                                  -scale, scale).astype(out_dtype)
    if d.init == "scaled":
        # conservative fan-in: product of all non-output dims (never
        # over-scales, even for stacked/3D projection tensors)
        fan_in = 1
        for s in d.shape[:-1]:
            fan_in *= s
        scale = d.scale if d.scale is not None else float(np.sqrt(1.0 / max(1, fan_in)))
        return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(out_dtype)
    # default: normal
    scale = d.scale if d.scale is not None else 0.02
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(out_dtype)


def init_params(schema: Dict[str, Any], key: jax.Array,
                dtype: Any = jnp.bfloat16) -> Dict[str, Any]:
    """Materialize real parameter arrays (smoke tests / small runs)."""
    def build(node: Dict[str, Any], prefix: str) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for k in sorted(node):
            v = node[k]
            path = f"{prefix}/{k}" if prefix else k
            if is_def(v):
                out[k] = _materialize(v, _path_key(key, path), dtype)
            else:
                out[k] = build(v, path)
        return out
    return build(schema, "")


def abstract_params(schema: Dict[str, Any],
                    dtype: Any = jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct tree — zero allocation, for .lower() dry-runs."""
    def build(node):
        out = {}
        for k, v in node.items():
            if is_def(v):
                out[k] = jax.ShapeDtypeStruct(v.shape, jnp.dtype(v.dtype or dtype))
            else:
                out[k] = build(v)
        return out
    return build(schema)


def axes_tree(schema: Dict[str, Any]) -> Dict[str, Any]:
    def build(node):
        return {k: (v.axes if is_def(v) else build(v)) for k, v in node.items()}
    return build(schema)


def shapes_tree(schema: Dict[str, Any]) -> Dict[str, Any]:
    def build(node):
        return {k: (v.shape if is_def(v) else build(v)) for k, v in node.items()}
    return build(schema)


def param_count(schema: Dict[str, Any]) -> int:
    total = 0
    for _, d in _iter_items(schema):
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total


def param_bytes(schema: Dict[str, Any], default_bytes: int = 2) -> int:
    total = 0
    for _, d in _iter_items(schema):
        n = 1
        for s in d.shape:
            n *= s
        itemsize = jnp.dtype(d.dtype).itemsize if d.dtype else default_bytes
        total += n * itemsize
    return total
