"""Logical-to-physical mesh layouts: the thread-placement analogue.

The paper (Section 3.2) shows that *where* threads land relative to the
topology decides cache behaviour and local-access ratio, and that the OS
default (free migration) is both slow and high-variance. On TPU the runtime
does not migrate programs, but the *assignment of logical mesh coordinates to
physical chips* plays the same role: it decides which collectives ride 1-hop
physical rings and which are diluted across the torus.

Layouts (see core.config.MeshLayout):
  DENSE   model-parallel groups contiguous (one torus row per TP group):
          TP collectives are 1-hop, DP collectives cross rows.
  SPARSE  data-parallel groups contiguous (one torus column per DP ring):
          DP collectives are 1-hop; TP groups spread across columns — each TP
          group spans all 16 columns' worth of distinct links (paper: maximize
          aggregate bandwidth).
  NONE    a fixed pseudo-random permutation, modeling the topology-oblivious
          "OS scheduler" baseline (deterministic so results are reproducible,
          but deliberately locality-free).

All layouts are permutations of the same device set, so the HLO program is
identical; the difference is priced by ``core.topology``.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.config import MeshLayout
from repro.core.topology import TorusTopology, ring_neighbor_hops


def _derangement(n: int, seed: int = 0xDA7A) -> np.ndarray:
    """Deterministic pseudo-random permutation of range(n)."""
    rng = np.random.RandomState(seed)
    return rng.permutation(n)


def layout_device_order(layout: MeshLayout, topo: TorusTopology) -> np.ndarray:
    """Return physical device indices arranged as the logical mesh grid.

    Output shape: (n_pods, xdim, ydim) -> logical ("pod", "data", "model")
    (single-pod callers squeeze the pod axis). Entry [p, d, m] is the physical
    chip index that hosts logical coordinate (pod=p, data=d, model=m).
    """
    n = topo.n_chips
    base = np.arange(n).reshape(topo.n_pods, topo.xdim, topo.ydim)
    if layout == MeshLayout.DENSE:
        # logical model axis == physical y (rows contiguous): TP 1-hop rings
        return base
    if layout == MeshLayout.SPARSE:
        # logical data axis == physical y: DP 1-hop rings, TP spread over x
        return base.transpose(0, 2, 1)
    # NONE: topology-oblivious permutation
    perm = _derangement(n)
    return perm.reshape(topo.n_pods, topo.xdim, topo.ydim)


def axis_rings(order: np.ndarray, axis: int) -> List[List[int]]:
    """Enumerate the device rings formed along one logical axis."""
    moved = np.moveaxis(order, axis, -1)
    flat = moved.reshape(-1, moved.shape[-1])
    return [list(map(int, row)) for row in flat]


def mean_axis_hops(layout: MeshLayout, topo: TorusTopology,
                   logical_axis: str) -> float:
    """Mean ring-neighbour hop distance for collectives over one axis."""
    order = layout_device_order(layout, topo)
    axis_index = {"pod": 0, "data": 1, "model": 2}[logical_axis]
    rings = axis_rings(order, axis_index)
    hops = [ring_neighbor_hops(topo, r) for r in rings if len(r) > 1]
    return float(np.mean(hops)) if hops else 0.0


def layout_report(topo: TorusTopology) -> dict:
    """Hop-dilution table for every layout x axis (benchmarks/thread_placement)."""
    report = {}
    for layout in MeshLayout:
        report[layout.value] = {
            ax: mean_axis_hops(layout, topo, ax)
            for ax in ("data", "model")
        }
    return report
