"""NUMA-distance model for TPU torus topologies.

The paper characterizes its machines by *relative NUMA node memory latency*
(Table 3: local 1.0, 1-hop 1.2 ... 3-hop 1.6 on machine A) and interconnect
bandwidth. The TPU analogue: chips in a pod form a 2D torus connected by ICI
links; pods are bridged by a much slower inter-pod tier (DCI). This module is
the framework's cost model for "remote memory access": given a logical mesh
layout it prices each collective in hop-weighted bytes, which is how the
SPARSE/DENSE/NONE thread-placement analogues are compared quantitatively
(the CPU-backend HLO is placement-agnostic, so this model supplies the
topology term the hardware would).

Hardware constants (TPU v5e, per the assignment):
  peak bf16 compute   197 TFLOP/s / chip
  HBM bandwidth       819 GB/s / chip
  ICI link bandwidth  ~50 GB/s / link  (4 links/chip on a 2D torus)
  inter-pod (DCI)     modeled at 1/8 of an ICI link per chip pair
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_LINK_BW = 50e9                # bytes/s per link
ICI_LINKS_PER_CHIP = 4            # 2D torus: +/-x, +/-y
DCI_BW = ICI_LINK_BW / 8          # inter-pod tier


@dataclass(frozen=True)
class TorusCoord:
    pod: int
    x: int
    y: int


@dataclass(frozen=True)
class TorusTopology:
    """``n_pods`` pods, each an ``xdim`` x ``ydim`` wrap-around torus."""

    n_pods: int = 1
    xdim: int = 16
    ydim: int = 16

    @property
    def chips_per_pod(self) -> int:
        return self.xdim * self.ydim

    @property
    def n_chips(self) -> int:
        return self.n_pods * self.chips_per_pod

    def coord(self, device_index: int) -> TorusCoord:
        pod, rem = divmod(device_index, self.chips_per_pod)
        x, y = divmod(rem, self.ydim)
        return TorusCoord(pod=pod, x=x, y=y)

    def hop_distance(self, a: int, b: int) -> float:
        """Torus manhattan distance; cross-pod hops carry a DCI penalty."""
        ca, cb = self.coord(a), self.coord(b)
        dx = min(abs(ca.x - cb.x), self.xdim - abs(ca.x - cb.x))
        dy = min(abs(ca.y - cb.y), self.ydim - abs(ca.y - cb.y))
        pod_penalty = 0.0
        if ca.pod != cb.pod:
            # crossing DCI costs at least a full pod traverse in hop
            # equivalents (bandwidth tier is 8x slower per topology spec)
            pod_penalty = self.xdim + self.ydim
        return dx + dy + pod_penalty

    # -- relative latency table, mirroring paper Table 3 -------------------
    def relative_latency(self, a: int, b: int) -> float:
        """Paper-style relative access latency (local = 1.0)."""
        d = self.hop_distance(a, b)
        return 1.0 + 0.2 * d


# ---------------------------------------------------------------------------
# Collective cost model
# ---------------------------------------------------------------------------
def ring_neighbor_hops(topo: TorusTopology, ring: Sequence[int]) -> float:
    """Mean torus hop distance between successive ring members.

    1.0 means the logical ring is a physical ring (each transfer is one ICI
    hop); larger values mean each ring step crosses multiple links and thus
    divides effective bandwidth.
    """
    n = len(ring)
    if n <= 1:
        return 0.0
    total = 0.0
    for i in range(n):
        total += topo.hop_distance(ring[i], ring[(i + 1) % n])
    return total / n


def ring_allreduce_seconds(nbytes: int, group: Sequence[int],
                           topo: TorusTopology) -> float:
    """Bidirectional-ring all-reduce: 2*(n-1)/n of the buffer crosses each
    link; hop dilution divides effective bandwidth."""
    n = len(group)
    if n <= 1:
        return 0.0
    hops = max(1.0, ring_neighbor_hops(topo, group))
    # two directions usable on a torus ring -> 2 links
    eff_bw = 2 * ICI_LINK_BW / hops
    return 2.0 * nbytes * (n - 1) / n / eff_bw


def all_gather_seconds(nbytes: int, group: Sequence[int],
                       topo: TorusTopology) -> float:
    n = len(group)
    if n <= 1:
        return 0.0
    hops = max(1.0, ring_neighbor_hops(topo, group))
    eff_bw = 2 * ICI_LINK_BW / hops
    return nbytes * (n - 1) / n / eff_bw


def all_to_all_seconds(nbytes: int, group: Sequence[int],
                       topo: TorusTopology) -> float:
    """All-to-all moves (n-1)/n of the buffer, but bisection-limited."""
    n = len(group)
    if n <= 1:
        return 0.0
    hops = max(1.0, ring_neighbor_hops(topo, group))
    # bisection of a ring of n chips with 2 links each
    eff_bw = 4 * ICI_LINK_BW / hops
    return nbytes * (n - 1) / n / eff_bw


COLLECTIVE_COSTS = {
    "all-reduce": ring_allreduce_seconds,
    "all-gather": all_gather_seconds,
    "reduce-scatter": all_gather_seconds,   # same wire bytes as all-gather
    "all-to-all": all_to_all_seconds,
    "collective-permute": lambda nbytes, group, topo: (
        nbytes / (ICI_LINK_BW * max(1.0, 1.0 / max(1.0, ring_neighbor_hops(topo, group))))
        if len(group) > 1 else 0.0),
}


# ---------------------------------------------------------------------------
# Simple aggregate roofline helpers (used by launch.dryrun / benchmarks)
# ---------------------------------------------------------------------------
def compute_seconds(total_flops: float, n_chips: int) -> float:
    return total_flops / (n_chips * PEAK_FLOPS_BF16)


def memory_seconds(total_bytes: float, n_chips: int) -> float:
    return total_bytes / (n_chips * HBM_BW)


def collective_seconds(total_bytes: float, n_chips: int,
                       links_per_chip: float = ICI_LINKS_PER_CHIP) -> float:
    """Flat assignment-mandated form: bytes / (chips x link_bw)."""
    return total_bytes / (n_chips * ICI_LINK_BW)
