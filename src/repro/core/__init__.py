"""Core: configs, placement policies, topology model, partitioning engine.

This package holds the paper's primary contribution adapted to TPU: the
application-agnostic placement system (memory placement policies, mesh
layouts / thread placement, allocator + OS-config knobs) that every workload
in the framework — analytics operators and LM architectures alike — runs
under without code changes.
"""
from repro.core.config import (
    AllocatorKind,
    ArchConfig,
    AttentionKind,
    HybridConfig,
    LM_SHAPES,
    MLAConfig,
    MeshLayout,
    MoEConfig,
    OSConfig,
    PaddedDims,
    PlacementPolicy,
    RWKVConfig,
    RopeKind,
    RunConfig,
    ShapeConfig,
    ShardingConfig,
    StepKind,
    TrainConfig,
    pad_to,
)
from repro.core.params import (
    ParamDef,
    abstract_params,
    axes_tree,
    init_params,
    param_bytes,
    param_count,
    pdef,
    shapes_tree,
)
from repro.core.partitioning import (
    DEFAULT_RULES,
    policy_state_spec,
    rules_with,
    spec_for,
    tree_shardings,
    tree_specs,
    validate_spec,
)
from repro.core.topology import (
    HBM_BW,
    ICI_LINK_BW,
    PEAK_FLOPS_BF16,
    TorusTopology,
)
