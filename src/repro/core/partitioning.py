"""Logical-axis partitioning engine (t5x-style) + placement policies.

Models annotate every parameter with *logical* axis names (("vocab","embed"),
("heads","head_dim","embed"), ...). This module maps logical axes onto mesh
axes through a rule table, applies the NUMA placement policy to *state*
arrays (optimizer moments, caches, shared tables), and provides the padding
helpers that keep every dimension divisible by its mesh axis.

The placement policies are the heart of the reproduction (paper Section 3.3):

  FIRST_TOUCH  state inherits the producing computation's sharding and is
               replicated along the data axes — each data-parallel group
               "first-touches" its own copy. Default-OS analogue.
  INTERLEAVE   state is additionally sharded round-robin over the data axes
               (ZeRO-1 for optimizer state; bucket-interleave for tables).
  LOCAL_ALLOC  per-shard private state (no cross-shard sharing).
  PREFERRED    pinned to one submesh slice. XLA SPMD cannot express "resident
               on slice x" inside one mesh, so PREFERRED lowers as replicated
               and its true cost (capacity pressure on x, remote access from
               everyone else) is priced by core.topology + the memory ledger.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import PlacementPolicy

# ---------------------------------------------------------------------------
# Logical-axis rules
# ---------------------------------------------------------------------------
# Default rule table for the production mesh ("pod", "data", "model").
# None -> replicated along that logical axis.
DEFAULT_RULES: Dict[str, Optional[Any]] = {
    # embeddings / projections
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "d_rnn": "model",
    # MoE
    "expert": "model",            # overridden to ("data","model") for big EP
    "expert_ff": None,
    # MLA latents
    "q_lora": None,
    "kv_lora": None,
    # rwkv
    "rwkv_heads": "model",
    "lora": None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "model",            # sequence-parallel residual stream
    # scan-stacked layer dim
    "layers": None,
}


def rules_with(overrides: Mapping[str, Any]) -> Dict[str, Any]:
    rules = dict(DEFAULT_RULES)
    rules.update(overrides)
    return rules


def _present(mesh: Mesh, axis: Any) -> Optional[Any]:
    """Drop mesh axes that don't exist (e.g. 'pod' on the single-pod mesh)."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return axis if axis in mesh.axis_names else None


def spec_for(logical_axes: Sequence[Optional[str]], rules: Mapping[str, Any],
             mesh: Mesh) -> P:
    """Map a tuple of logical axis names to a PartitionSpec on ``mesh``."""
    parts = []
    used: set = set()
    for name in logical_axes:
        axis = _present(mesh, rules.get(name)) if name else None
        # a mesh axis may appear at most once in a spec
        if axis is not None:
            flat = axis if isinstance(axis, tuple) else (axis,)
            if any(a in used for a in flat):
                axis = None
            else:
                used.update(flat)
        parts.append(axis)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def axis_size(mesh: Mesh, axis: Any) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def validate_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop sharding on any dim the axis size does not divide (with a
    preference for keeping the spec; callers pad dims ahead of time)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    fixed = []
    for dim, axis in zip(shape, parts):
        size = axis_size(mesh, axis)
        fixed.append(axis if size > 1 and dim % size == 0 else
                     (axis if size == 1 else None))
    while fixed and fixed[-1] is None:
        fixed.pop()
    return P(*fixed)


# ---------------------------------------------------------------------------
# Placement policies applied to state arrays
# ---------------------------------------------------------------------------
def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def policy_state_spec(policy: PlacementPolicy, base_spec: P,
                      shape: Sequence[int], mesh: Mesh) -> P:
    """Sharding for a *state* array whose computation-sharding is base_spec.

    FIRST_TOUCH keeps base_spec. INTERLEAVE additionally spreads the largest
    unsharded-and-divisible dimension over the data axes (round-robin page
    interleave analogue / ZeRO-1). LOCAL_ALLOC and PREFERRED lower the same
    as FIRST_TOUCH / replicated; their semantics live in the cost model.
    """
    base_spec = validate_spec(shape, base_spec, mesh)
    if policy != PlacementPolicy.INTERLEAVE:
        return base_spec
    parts = list(base_spec) + [None] * (len(shape) - len(base_spec))
    used: set = set()
    for axis in parts:
        if axis is None:
            continue
        for a in (axis if isinstance(axis, tuple) else (axis,)):
            used.add(a)
    data_axes = tuple(a for a in _data_axes(mesh) if a not in used)
    if not data_axes:
        return base_spec
    dsize = axis_size(mesh, data_axes)
    # pick the largest dim that is unsharded and divisible by the data axes
    best_dim, best_len = -1, 0
    for i, (dim, axis) in enumerate(zip(shape, parts)):
        if axis is None and dim % dsize == 0 and dim > best_len:
            best_dim, best_len = i, dim
    if best_dim < 0:
        return base_spec
    parts[best_dim] = data_axes if len(data_axes) > 1 else data_axes[0]
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# Tree utilities over (schema | params, logical-axes) trees
# ---------------------------------------------------------------------------
def tree_specs(axes_tree: Any, rules: Mapping[str, Any], mesh: Mesh,
               shapes_tree: Any) -> Any:
    """Build a PartitionSpec tree from logical-axes + shapes trees."""
    def one(axes, shape):
        return validate_spec(shape, spec_for(axes, rules, mesh), mesh)
    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))


def tree_shardings(axes_tree: Any, rules: Mapping[str, Any], mesh: Mesh,
                   shapes_tree: Any) -> Any:
    specs = tree_specs(axes_tree, rules, mesh, shapes_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
