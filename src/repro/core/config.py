"""Configuration system for the repro framework.

Every architecture, input shape, placement decision and runtime knob is a
frozen dataclass so that configs are hashable (usable as jit static args and
cache keys) and serializable (checkpoint metadata, experiment ledgers).

The paper's four experimental axes (allocator, thread placement, memory
placement policy, OS configuration) appear here as first-class,
application-agnostic knobs on ``RunConfig`` — any workload (the analytics
engine or any of the 10 LM architectures) picks them up without code changes.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Paper axis 3: memory placement policies (Section 3.3 of the paper)
# ---------------------------------------------------------------------------
class PlacementPolicy(enum.Enum):
    """NUMA memory-placement policies mapped to mesh shardings.

    FIRST_TOUCH  state is owned by the shard group that produced it and is
                 replicated along the data axis (the OS-default analogue).
    INTERLEAVE   state is sharded round-robin across every device in the mesh
                 (the paper's winner for shared state).
    LOCAL_ALLOC  state is private to each consuming shard; no shared copy.
    PREFERRED    state pinned to one submesh slice (``preferred_index``).
    """

    FIRST_TOUCH = "first_touch"
    INTERLEAVE = "interleave"
    LOCAL_ALLOC = "local_alloc"
    PREFERRED = "preferred"


# ---------------------------------------------------------------------------
# Paper axis 2: thread placement (Section 3.2) -> logical-to-physical layout
# ---------------------------------------------------------------------------
class MeshLayout(enum.Enum):
    """How logical mesh axes map onto the physical torus.

    NONE    device enumeration order (the "OS free to migrate" baseline).
    SPARSE  model-parallel groups spread across distinct ICI neighbourhoods,
            maximizing aggregate link bandwidth (paper's Sparse affinity).
    DENSE   model-parallel groups packed into adjacent chips, minimizing hop
            count inside a group (paper's Dense affinity).
    """

    NONE = "none"
    SPARSE = "sparse"
    DENSE = "dense"


# ---------------------------------------------------------------------------
# Paper axis 1: allocator selection (Section 3.1)
# ---------------------------------------------------------------------------
class AllocatorKind(enum.Enum):
    BUMP = "bump"          # ptmalloc analogue: one global region, one lock
    ARENA = "arena"        # jemalloc analogue: per-stream arenas, round robin
    SLAB = "slab"          # tbbmalloc/tcmalloc analogue: size-class slabs
    HOARD = "hoard"        # Hoard analogue: global heap + per-stream heaps


# ---------------------------------------------------------------------------
# Paper axis 4: OS configuration (Section 3.4)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class OSConfig:
    """Analogue of the paper's kernel-level switches.

    ``auto_rebalance``   AutoNUMA analogue: automatically reshard live state
                         toward its policy-ideal placement between steps
                         (priced as extra collective traffic).
    ``page_tokens``      THP analogue for the paged KV cache: tokens per page
                         (16 = 4KB-ish small page, 512 = 2MB-ish huge page).
    ``granule_bytes``    allocation granule of the device arena allocators.
    """

    auto_rebalance: bool = True          # Linux default: on (harmful, per paper)
    page_tokens: int = 512               # THP default: on (large pages)
    granule_bytes: int = 2 * 1024 * 1024

    def tuned(self) -> "OSConfig":
        """The paper's recommended configuration (AutoNUMA off, THP off)."""
        return dataclasses.replace(self, auto_rebalance=False, page_tokens=16,
                                   granule_bytes=4 * 1024)


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------
class AttentionKind(enum.Enum):
    GQA = "gqa"            # grouped-query attention (covers MHA/MQA)
    MLA = "mla"            # deepseek multi-head latent attention
    NONE = "none"          # attention-free (rwkv)
    HYBRID = "hybrid"      # recurrentgemma: RG-LRU + local attention pattern


class RopeKind(enum.Enum):
    NONE = "none"
    ROPE = "rope"
    MROPE = "mrope"        # qwen2-vl multimodal 3-section rope


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden size
    n_shared_experts: int = 0          # deepseek-style always-on experts
    n_dense_layers: int = 0            # leading layers that stay dense
    dense_d_ff: Optional[int] = None   # FFN width of the leading dense layers
    router_aux_weight: float = 0.001   # load-balancing aux loss
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma block pattern: ``pattern`` repeats over layers."""
    pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")
    window: int = 2048                 # local attention window
    d_rnn: Optional[int] = None        # RG-LRU width (defaults to d_model)
    conv_width: int = 4                # temporal conv1d width


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64               # rank of data-dependent decay LoRA
    mix_lora: int = 32                 # rank of token-shift mixing LoRA


@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture with exact published dimensions."""

    name: str
    family: str                        # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # defaults to d_model // n_heads
    attention: AttentionKind = AttentionKind.GQA
    qk_norm: bool = False              # qwen3
    qkv_bias: bool = False             # qwen2
    rope: RopeKind = RopeKind.ROPE
    rope_theta: float = 10_000.0
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    hybrid: Optional[HybridConfig] = None
    rwkv: Optional[RWKVConfig] = None
    mtp: bool = False                  # deepseek multi-token prediction head
    n_codebooks: int = 0               # musicgen: parallel codebook heads
    vlm: bool = False                  # qwen2-vl: patch-embedding side input
    n_patches: int = 1024              # VLM stub: patches per example
    max_seq_len: int = 1 << 20
    source: str = ""                   # provenance citation

    # ---- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True when serving cost per token does not grow with context."""
        return self.attention in (AttentionKind.NONE, AttentionKind.HYBRID)

    def param_count(self) -> int:
        """Analytic parameter count (unpadded), for 6ND roofline math."""
        d, v, L = self.d_model, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.attention == AttentionKind.MLA:
            m = self.mla
            att = (d * m.q_lora_rank
                   + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                   + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                   + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                   + self.n_heads * m.v_head_dim * d)
        elif self.attention == AttentionKind.NONE:
            r = self.rwkv or RWKVConfig()
            att = 4 * d * d + d * (5 * r.decay_lora + 10 * r.mix_lora)  # rwkv time mix
        else:
            att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn_dense = 3 * d * self.d_ff  # swiglu: gate, up, down
        per_layer = att + ffn_dense
        total = emb + L * per_layer
        if self.moe is not None:
            moe_layers = L - self.moe.n_dense_layers
            expert_ffn = 3 * d * self.moe.d_expert
            moe_per_layer = (self.moe.n_experts + self.moe.n_shared_experts) * expert_ffn
            total = (emb + L * att + self.moe.n_dense_layers * ffn_dense
                     + moe_layers * moe_per_layer)
        if self.hybrid is not None:
            # hybrid: replace attention in rglru layers with the RG-LRU block
            h = self.hybrid
            d_rnn = h.d_rnn or d
            n_rglru = sum(1 for i in range(L) if h.pattern[i % len(h.pattern)] == "rglru")
            rglru = 2 * d * d_rnn + d_rnn * d + h.conv_width * d_rnn + 2 * d_rnn
            total += n_rglru * (rglru - att)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        moe_layers = L - self.moe.n_dense_layers
        expert_ffn = 3 * d * self.moe.d_expert
        inactive = (self.moe.n_experts - self.moe.top_k) * expert_ffn * moe_layers
        return int(self.param_count() - inactive)


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------
class StepKind(enum.Enum):
    TRAIN = "train"        # lowers train_step
    PREFILL = "prefill"    # lowers prefill (serve) step over full sequence
    DECODE = "decode"      # lowers serve_step: one token, KV cache of seq_len


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: StepKind
    seq_len: int
    global_batch: int


LM_SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", StepKind.TRAIN, 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", StepKind.PREFILL, 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", StepKind.DECODE, 32_768, 128),
    "long_500k": ShapeConfig("long_500k", StepKind.DECODE, 524_288, 1),
}


# ---------------------------------------------------------------------------
# Run configuration: arch x shape x paper knobs x training knobs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardingConfig:
    """Parallelism degrees and options. Axis sizes come from the mesh.

    ``strategy``:
      "tp"    Megatron tensor parallelism over the model axis (+ optional
              sequence-parallel residual stream) — the paper-faithful
              baseline layout.
      "fsdp"  fully-sharded data parallelism: batch over EVERY mesh axis,
              parameters 2D-sharded for storage and gathered per layer —
              the beyond-paper §Perf layout for models whose TP collectives
              dominate (INTERLEAVE applied to the parameters themselves).
    """

    policy: PlacementPolicy = PlacementPolicy.INTERLEAVE
    mesh_layout: MeshLayout = MeshLayout.SPARSE
    strategy: str = "tp"                 # "tp" | "fsdp"
    preferred_index: int = 0
    sequence_parallel: bool = True       # shard residual stream seq dim on model axis
    expert_parallel_data: bool = False   # MoE experts across data x model axes
    gradient_compression: bool = False   # int8 + error feedback DP all-reduce
    decode_dshard: bool = False          # decode KV cache sharded over head_dim
    donate_state: bool = True


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    accum_steps: int = 1                # gradient accumulation microbatches
    grad_accum_dtype: str = "float32"   # "bfloat16" halves the accum buffer
    moment_dtype: str = "float32"       # "bfloat16" halves optimizer HBM
    master_weights: bool = True         # fp32 master copy (sharded per policy)
    remat: str = "block"                # none | block | full
    z_loss: float = 0.0


@dataclass(frozen=True)
class RunConfig:
    arch: ArchConfig
    shape: ShapeConfig
    sharding: ShardingConfig = ShardingConfig()
    train: TrainConfig = TrainConfig()
    os: OSConfig = OSConfig().tuned()    # paper recommendation by default
    allocator: AllocatorKind = AllocatorKind.SLAB
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    def cache_key(self) -> str:
        return f"{self.arch.name}|{self.shape.name}|{self.sharding.policy.value}"


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------
def pad_to(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= n."""
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    return int(math.ceil(n / multiple) * multiple)


@dataclass(frozen=True)
class PaddedDims:
    """TP-divisibility padding decisions (exact-output zero padding).

    Padded query heads have zero Wq rows and zero Wo columns, so their
    contribution to the output is exactly zero; padded KV heads are only
    attended to by padded query heads. Vocab is padded to the MXU lane
    multiple; padded logits rows are masked to -inf before the softmax.
    """

    n_heads: int
    n_kv_heads: int
    vocab_size: int
    d_ff: int

    @staticmethod
    def for_tp(arch: ArchConfig, tp: int, lane: int = 128) -> "PaddedDims":
        n_heads = pad_to(arch.n_heads, tp)
        n_kv = pad_to(arch.n_kv_heads, tp) if arch.n_kv_heads else 0
        # keep q:kv group structure intact: q heads must divide evenly by kv
        if n_kv:
            group = max(1, n_heads // n_kv)
            n_heads = n_kv * group
            while n_heads < arch.n_heads:
                group += 1
                n_heads = n_kv * group
            n_heads = pad_to(n_heads, tp)
            if n_heads % n_kv:
                n_heads = pad_to(n_heads, n_kv * tp // math.gcd(n_kv, tp))
        vocab = pad_to(arch.vocab_size, max(lane, tp))
        d_ff = pad_to(arch.d_ff, tp)
        return PaddedDims(n_heads=n_heads, n_kv_heads=n_kv, vocab_size=vocab,
                         d_ff=d_ff)
