"""Benchmark utilities: timing + subprocess meshes (benches themselves see
one device; multi-device figures run in child processes)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Callable, List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def time_fn(fn: Callable[[], object], *, warmup: int = 2,
            iters: int = 5) -> float:
    """Median wall-time per call in microseconds (jax results blocked)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run_in_mesh(code: str, n_devices: int = 8, timeout: int = 600) -> dict:
    """Run code in a child with N fake devices; code must print one JSON."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])
