"""Roofline table: reads the dry-run JSON reports (experiments/dryrun) and
emits one row per (arch x shape x mesh) — the §Roofline deliverable."""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import REPO, Row

DRYRUN_DIR = os.path.join(REPO, "experiments", "dryrun")


def load_reports(pattern: str = "*.json"):
    reports = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        try:
            reports.append(json.load(open(f)))
        except Exception:
            continue
    return reports


def run() -> List[Row]:
    rows: List[Row] = []
    for r in load_reports():
        tag = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        if r.get("policy", "interleave") != "interleave" or \
           not r.get("sequence_parallel", True):
            tag += f"|{r.get('policy')}{'' if r.get('sequence_parallel', True) else '|nosp'}"
        if r["status"] != "ok":
            rows.append((f"roofline_{tag}", 0.0, r["status"]))
            continue
        rf = r["roofline"]
        rows.append((
            f"roofline_{tag}",
            rf["step_s_lower_bound"] * 1e6,
            f"bottleneck={rf['bottleneck']};compute_s={rf['compute_s']:.3f};"
            f"memory_s={rf['memory_s']:.3f};collective_s={rf['collective_s']:.3f};"
            f"mfu_bound={rf['mfu_bound'] or 0:.4f};"
            f"GB/dev={r['bytes_per_device']/1e9:.1f};"
            f"fits={r['fits_16gb']};useful={r['useful_flops_ratio'] or 0:.3f}"))
    if not rows:
        rows.append(("roofline_missing", 0.0,
                     "run repro.launch.dryrun --all first"))
    return rows
