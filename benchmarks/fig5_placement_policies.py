"""Paper Figure 5: placement policies x auto-rebalance (AutoNUMA analogue).

Measured on a real (fake-device) 8-way mesh in a subprocess: wall time of
W1 (holistic median) and W2 (distributive count) under each policy, plus
the AutoNUMA analogue appended to FIRST_TOUCH, plus the LAR analogue
(local bytes / total bytes from the compiled collective mix).

Reproduction targets (paper 4.3.1): INTERLEAVE fastest for shared-state
aggregation; auto-rebalance only helps the pathological placements;
holistic aggregation punishes replication-based policies hardest.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, run_in_mesh

CODE = """
import json, time, numpy as np, jax, jax.numpy as jnp
from repro.core.config import PlacementPolicy
from repro.analytics.engine import dist_count, dist_median
from repro.analytics.datasets import moving_cluster

mesh = jax.make_mesh((8,), ("data",))
G, N = 4096, 1 << 20
ds = moving_cluster(N, G, seed=3)
keys = jnp.asarray(ds.keys); vals = jnp.asarray(ds.vals)

def bench(fn, *args):
    out = jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[2] * 1e6

res = {}
for pol in PlacementPolicy:
    for auto in ((False, True) if pol == PlacementPolicy.FIRST_TOUCH else (False,)):
        fn = jax.jit(dist_count(mesh, pol, G, auto_rebalance=auto))
        hlo = fn.lower(keys).compile().as_text()
        wire = sum(hlo.count(f" {c}(") for c in
                   ("all-reduce", "all-gather", "all-to-all",
                    "reduce-scatter", "collective-permute"))
        tag = pol.value + ("+auto" if auto else "")
        res[f"w2_{tag}"] = {"us": bench(fn, keys), "collectives": wire}
for pol in (PlacementPolicy.FIRST_TOUCH, PlacementPolicy.INTERLEAVE,
            PlacementPolicy.PREFERRED):
    fn = jax.jit(dist_median(mesh, pol, G))
    res[f"w1_{pol.value}"] = {"us": bench(fn, keys, vals)}
print(json.dumps(res))
"""


def run() -> List[Row]:
    res = run_in_mesh(CODE, n_devices=8, timeout=900)
    rows: List[Row] = []
    for name, d in res.items():
        derived = ";".join(f"{k}={v}" for k, v in d.items() if k != "us")
        rows.append((f"fig5_{name}", d["us"], derived))
    return rows
