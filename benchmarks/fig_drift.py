"""Estimator-drift summary: how far the planner's static row estimates
sit from observed execution (ISSUE 7 telemetry, beyond-paper).

Runs two representative plans under telemetry whose runtime behavior the
static cost model cannot see from shapes alone:

  * ``selective_join`` — a highly selective filter ahead of a join: the
    planner prices the join and the downstream aggregate for the full
    scan cardinality, but only ~1% of rows survive;
  * ``sparse_groups`` — a grouped aggregate whose declared group domain
    is mostly unoccupied (keys drawn from a small subset).

Rows:
  fig_drift_<plan>           tracked end-to-end latency (us) — the cost
                             of running WITH telemetry enabled
  fig_drift_report_rows      drifting (node, stat) entries in the global
                             drift report — gated against an ABSOLUTE
                             floor of 1.0 in run.py: the benchmark must
                             demonstrate the detector actually fires
  fig_drift_max_dev_<kind>   max |observed/estimated| deviation ratio
                             per Decision kind (>= 1.0; 1.0 = estimates
                             exact) — the ``drift_summary()`` rows the
                             --json recording carries for the trajectory

The distributed drift axes (Exchange moved rows, Compact occupancy) need
a mesh and are gated by scripts/drift_gate.py instead; this module stays
in-process so the drift report is produced on every CI sweep.
"""
import time

import numpy as np


def _tables(rng, n, d):
    import jax.numpy as jnp
    return {
        "fact": {"fk": jnp.asarray(rng.randint(0, d, n).astype(np.int32)),
                 "k": jnp.asarray(rng.randint(0, 40, n).astype(np.int32)),
                 "v": jnp.asarray(rng.rand(n).astype(np.float32))},
        "dim": {"pk": jnp.asarray(np.arange(d, dtype=np.int32)),
                "dv": jnp.asarray(rng.rand(d).astype(np.float32))},
    }


def run():
    import jax
    from repro.analytics import plan as L
    from repro.analytics import planner, telemetry

    rng = np.random.RandomState(0)
    n, d, g = 1 << 14, 256, 512
    tables = _tables(rng, n, d)
    plans = [
        ("selective_join", L.LogicalPlan(
            L.scan("fact").filter(L.col("v") < 0.01)
            .join(L.scan("dim"), "fk", "pk", {"dv": "dv"})
            .aggregate("fk", d, c=("count", "v"), m=("max", "dv")),
            ("c", "m"))),
        # keys only occupy 40 of the declared 512 groups
        ("sparse_groups", L.LogicalPlan(
            L.scan("fact").aggregate("k", g, s=("sum", "v"),
                                     q=("median", "v")), ("s", "q"))),
    ]
    prev = planner.current_cost_profile()
    planner.set_cost_profile(None)
    telemetry.registry().clear()
    rows = []
    try:
        with telemetry.recording():
            ctx = planner.ExecutionContext(executor="cost")
            for name, p in plans:
                cp = planner.compile_plan(p, tables, ctx)
                jax.block_until_ready(list(cp(tables).values()))  # warm
                t0 = time.perf_counter()
                jax.block_until_ready(list(cp(tables).values()))
                rows.append((f"fig_drift_{name}",
                             (time.perf_counter() - t0) * 1e6,
                             "telemetry-tracked local run"))
        report = telemetry.registry().drift_report()
        summary = telemetry.registry().drift_summary()
    finally:
        planner.set_cost_profile(prev)
    rows.append(("fig_drift_report_rows", float(len(report)),
                 "drifting (node.stat) entries — floor >= 1"))
    for kind in sorted(summary):
        rows.append((f"fig_drift_max_dev_{kind}", float(summary[kind]),
                     "max obs/est deviation ratio (1.0 = exact)"))
    return rows
