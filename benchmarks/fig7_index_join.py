"""Paper Figure 7: index nested-loop join (W4) — index build + probe times
for the three TPU-adapted index kinds (radix=ART analogue, sorted=B+Tree
leaf/SkipList analogue, hash=Masstree analogue), plus the W3 hash join for
reference. Reproduction target: the radix-bucketed index probes fastest
(Fig 7a: ART wins), build times stay competitive.

Also measures the planner's two DISTRIBUTED join lowerings on an 8-device
subprocess mesh — broadcast (all-gather the build side) vs key-partitioned
(route both sides by join-key hash) — for a small and a large build side.
Reproduction target (paper Fig 5-7 placement story): broadcast wins while
the build side is a small dimension table; partitioned wins once the build
side rivals the probe side, and the wire-cost model picks each winner
automatically."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, run_in_mesh, time_fn
from repro.analytics import planner
from repro.analytics.datasets import blanas_join
from repro.analytics.dist_join_bench import (chain_code, exchange_code,
                                             pushdown_code, sweep_code,
                                             topk_code)
from repro.analytics.join import (build_hash_index, build_radix_index,
                                  build_sorted_index, hash_join, index_join,
                                  probe_hash_index, probe_radix_index,
                                  probe_sorted_index)

DIST_PROBE = 1 << 18
DIST_BUILDS = {"small_build": 1 << 10, "large_build": 1 << 18}
DIST_DEVICES = 8
PUSHDOWN_ROWS, PUSHDOWN_GROUPS = 1 << 18, 1 << 9
CHAIN_ROWS, CHAIN_DIM = 1 << 17, 1 << 15
EXCHANGE_PROBE, EXCHANGE_BUILD = 1 << 18, 1 << 14
TOPK_ROWS, TOPK_GROUPS, TOPK_K = 1 << 18, 1 << 14, 16


def run() -> List[Row]:
    rows: List[Row] = []
    jd = blanas_join(1 << 16, 1 << 20, seed=4)   # 64K : 1M (paper's 1:16)
    bk, bv, pk = (jnp.asarray(jd.build_keys), jnp.asarray(jd.build_vals),
                  jnp.asarray(jd.probe_keys))

    builders = {
        "radix": (jax.jit(build_radix_index), probe_radix_index),
        "sorted": (jax.jit(build_sorted_index), probe_sorted_index),
        "hash": (jax.jit(build_hash_index), probe_hash_index),
    }
    for name, (build, probe) in builders.items():
        us_build = time_fn(lambda b=build: b(bk, bv))
        idx = jax.block_until_ready(build(bk, bv))
        # jit converts static NamedTuple int fields to arrays: restore them
        for f in ("bits", "capacity", "max_probes"):
            if hasattr(idx, f):
                idx = idx._replace(**{f: int(getattr(idx, f))})
        probe_j = jax.jit(lambda keys, idx=idx, p=probe: p(idx, keys)[0].sum())
        us_probe = time_fn(lambda: probe_j(pk))
        rows.append((f"fig7_build_{name}", us_build, ""))
        rows.append((f"fig7_probe_{name}", us_probe,
                     f"probes={pk.shape[0]}"))
    us = time_fn(lambda: hash_join(bk, bv, pk, n_partitions=64, mode="ref"))
    rows.append(("fig7_w3_hash_join_adhoc", us, "build+probe per query"))
    return rows


def run_dist() -> List[Row]:
    """Distributed join lowerings: broadcast vs key-partitioned on an
    8-device subprocess mesh (registered as its own ``fig7_dist`` module
    in run.py so --skip-slow can drop it with the other subprocess-mesh
    figures; uses the same measurement snippet scripts/calibrate_costs.py
    fits dist_route_factor from)."""
    rows: List[Row] = []
    dist = run_in_mesh(
        sweep_code(probe=DIST_PROBE, builds=list(DIST_BUILDS.values()),
                   devices=DIST_DEVICES),
        n_devices=DIST_DEVICES, timeout=900)
    for tag, build_n in DIST_BUILDS.items():
        chosen = planner.choose_dist_join(
            DIST_PROBE, build_n, DIST_DEVICES,
            planner.ExecutionContext(executor="xla"))
        for strat in ("broadcast", "partitioned"):
            rows.append((f"fig7_dist_join_{tag}_{strat}",
                         dist[str(build_n)][strat],
                         f"build={build_n};probe={DIST_PROBE};"
                         f"cost_model_picks={chosen}"))

    # aggregate push-down: the same distributed group-by with the
    # PPartialAggregate split forced on vs off — the physical plan's
    # estimated moved rows shrink from ~n_rows/shard to ~n_groups
    pd = run_in_mesh(pushdown_code(rows=PUSHDOWN_ROWS,
                                   groups=PUSHDOWN_GROUPS,
                                   devices=DIST_DEVICES),
                     n_devices=DIST_DEVICES, timeout=900)
    for tag in ("pushdown", "no_pushdown"):
        rows.append((f"fig7_dist_agg_{tag}", pd[tag]["us"],
                     f"rows={PUSHDOWN_ROWS};groups={PUSHDOWN_GROUPS};"
                     f"moved_rows={pd[tag]['moved_rows']}"))

    # hash-Exchange routing LAYOUT pass: the same partitioned join with
    # the per-row send layout computed by the stable argsort vs the
    # radix-histogram prefix sums (both forced), plus which one the cost
    # model's static exchange_costs crossover picks at this size — the
    # two lowerings are bit-identical, so this row is purely wall-clock
    exch = run_in_mesh(exchange_code(build=EXCHANGE_BUILD,
                                     probes=[EXCHANGE_PROBE],
                                     devices=DIST_DEVICES),
                       n_devices=DIST_DEVICES, timeout=900)
    er = exch[str(EXCHANGE_PROBE)]
    for impl in ("argsort", "radix"):
        rows.append((f"fig7_dist_exchange_{impl}", er[impl],
                     f"probe={EXCHANGE_PROBE};build={EXCHANGE_BUILD};"
                     f"moved_rows={er['moved_rows']};"
                     f"cost_model_picks={er['cost_picks']}"))

    # distributed TopK: the replicated lowering selects on the merged
    # (replicated) group table; the candidates lowering converges only
    # k rows per shard through a gather Exchange — both bit-identical
    # (asserted in the child), so the row is wall-clock + wire volume
    tk = run_in_mesh(topk_code(rows=TOPK_ROWS, groups=TOPK_GROUPS,
                               k=TOPK_K, devices=DIST_DEVICES),
                     n_devices=DIST_DEVICES, timeout=900)
    for mode in ("replicated", "candidates"):
        rows.append((f"fig7_dist_topk_{mode}", tk[mode],
                     f"rows={TOPK_ROWS};groups={TOPK_GROUPS};k={TOPK_K};"
                     f"moved_rows={tk['moved_rows']};"
                     f"observed_moved={tk['observed_moved']};"
                     f"wire_budget={tk['wire_budget']};"
                     f"cost_model_picks={tk['cost_picks']}"))

    # chained partitioned joins: occupancy-aware Compact bounds the
    # routed-buffer growth between hops (the max buffer is read off the
    # physical plan, the wall-clock off the execution)
    ch = run_in_mesh(chain_code(rows=CHAIN_ROWS, dim=CHAIN_DIM,
                                devices=DIST_DEVICES),
                     n_devices=DIST_DEVICES, timeout=900)
    for tag in ("compact", "no_compact"):
        rows.append((f"fig7_dist_chain_{tag}", ch[tag]["us"],
                     f"rows={CHAIN_ROWS};dim={CHAIN_DIM};"
                     f"max_buffer_rows={ch[tag]['max_buffer_rows']}"))
    return rows
