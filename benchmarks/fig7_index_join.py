"""Paper Figure 7: index nested-loop join (W4) — index build + probe times
for the three TPU-adapted index kinds (radix=ART analogue, sorted=B+Tree
leaf/SkipList analogue, hash=Masstree analogue), plus the W3 hash join for
reference. Reproduction target: the radix-bucketed index probes fastest
(Fig 7a: ART wins), build times stay competitive."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.analytics.datasets import blanas_join
from repro.analytics.join import (build_hash_index, build_radix_index,
                                  build_sorted_index, hash_join, index_join,
                                  probe_hash_index, probe_radix_index,
                                  probe_sorted_index)


def run() -> List[Row]:
    rows: List[Row] = []
    jd = blanas_join(1 << 16, 1 << 20, seed=4)   # 64K : 1M (paper's 1:16)
    bk, bv, pk = (jnp.asarray(jd.build_keys), jnp.asarray(jd.build_vals),
                  jnp.asarray(jd.probe_keys))

    builders = {
        "radix": (jax.jit(build_radix_index), probe_radix_index),
        "sorted": (jax.jit(build_sorted_index), probe_sorted_index),
        "hash": (jax.jit(build_hash_index), probe_hash_index),
    }
    for name, (build, probe) in builders.items():
        us_build = time_fn(lambda b=build: b(bk, bv))
        idx = jax.block_until_ready(build(bk, bv))
        # jit converts static NamedTuple int fields to arrays: restore them
        for f in ("bits", "capacity", "max_probes"):
            if hasattr(idx, f):
                idx = idx._replace(**{f: int(getattr(idx, f))})
        probe_j = jax.jit(lambda keys, idx=idx, p=probe: p(idx, keys)[0].sum())
        us_probe = time_fn(lambda: probe_j(pk))
        rows.append((f"fig7_build_{name}", us_build, ""))
        rows.append((f"fig7_probe_{name}", us_probe,
                     f"probes={pk.shape[0]}"))
    us = time_fn(lambda: hash_join(bk, bv, pk, n_partitions=64, mode="ref"))
    rows.append(("fig7_w3_hash_join_adhoc", us, "build+probe per query"))
    return rows
