"""Paper Figures 8/9: TPC-H (W5) under default vs tuned configuration.

Fig 8 analogue: all five queries, default configuration (coarse operator
granularity + an auto-rebalance resharding pass — the THP+AutoNUMA-on
analogue) vs tuned (paper recommendation). Fig 9 analogue: Q5/Q18 under
the buffer-manager tunings (allocator override analogue).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.analytics.tpch import QUERIES, generate


def run() -> List[Row]:
    rows: List[Row] = []
    data = generate(scale=0.02, seed=0)

    # AutoNUMA analogue measured in isolation: the balancer's migration
    # pass rewrites every hot column (pure added bandwidth for an
    # already-placed workload — paper 4.3.1). Default config = query +
    # this pass; tuned = query alone. Measuring the pass separately keeps
    # the comparison deterministic (inline timing is jitter-bound at µs
    # scale on this container).
    li = data.table("lineitem")
    migrate = jax.jit(lambda: sum(
        (li.col(c).astype(jnp.float32) * 1.000001).sum()
        for c in li.columns))
    us_migration = time_fn(migrate, iters=9)
    rows.append(("fig8_autonuma_migration_pass", us_migration,
                 f"rows={li.n_rows};cols={len(li.columns)}"))

    for name, qfn in QUERIES.items():
        tuned = jax.jit(lambda qfn=qfn: qfn(data))
        us_tuned = time_fn(tuned, iters=9)
        us_default = us_tuned + us_migration
        gain = (us_default - us_tuned) / us_default * 100
        rows.append((f"fig8_tpch_{name}_default", us_default,
                     "query+migration pass"))
        rows.append((f"fig8_tpch_{name}_tuned", us_tuned,
                     f"latency_reduction={gain:.1f}%"))
    return rows
