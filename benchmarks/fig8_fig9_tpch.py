"""Paper Figures 8/9: TPC-H (W5) under default vs tuned configuration.

A genuine default-vs-tuned measurement over the SAME queries:

  default        the seed executor's behavior: ``jax.jit(lambda: q(...))()``
                 per call — re-traces and re-compiles every time with the
                 tables baked in as constants, and runs the naive XLA plan
                 (one segment op per aggregate). The THP+AutoNUMA-on
                 "just run it" configuration.
  xla_plancached the same XLA plan behind the plan cache (tables traced,
                 compiled once) — isolates how much of the win is caching.
  tuned          plan-cached + kernel-preferring executor: fused
                 multi-aggregate sweeps and pooled join indexes (the
                 paper's partition + per-thread-table recipe).
  planner        the cost-based physical planner (executor="cost"): per
                 Aggregate it picks XLA segment ops vs dense fused vs
                 range-partitioned fused from (n_rows, n_groups, n_cols) —
                 in particular, large-domain single-aggregate queries
                 (q3/q18) stay on segment ops instead of paying the
                 range-partition argsort the blanket "kernel" preference
                 forces on them.

Fig 9 analogue: Q5/Q18 — the paper's allocator case studies — default vs
tuned configuration on the join-heavy queries (the buffer-manager axis).
"""
from __future__ import annotations

from typing import Dict, List

import jax

from benchmarks.common import Row, time_fn
from repro.analytics.tpch import QUERIES, clear_plan_cache, generate, run_query


def run() -> List[Row]:
    rows: List[Row] = []
    data = generate(scale=0.02, seed=0)
    tables = data.as_jax()
    clear_plan_cache()

    tuned_us: Dict[str, float] = {}
    default_us: Dict[str, float] = {}
    for name, qfn in QUERIES.items():
        def default_call(qfn=qfn):
            # seed behavior: fresh jit per call -> per-call retrace+compile
            return jax.jit(lambda: qfn(tables, executor="xla"))()
        us_default = time_fn(default_call, warmup=0, iters=3)

        us_cached = time_fn(
            lambda name=name: run_query(name, tables, executor="xla"),
            iters=9)
        us_tuned = time_fn(
            lambda name=name: run_query(name, tables, executor="kernel"),
            iters=9)
        us_planner = time_fn(
            lambda name=name: run_query(name, tables, executor="cost"),
            iters=9)
        default_us[name], tuned_us[name] = us_default, us_tuned

        rows.append((f"fig8_tpch_{name}_default", us_default,
                     "per-call jit + naive XLA plan"))
        rows.append((f"fig8_tpch_{name}_xla_plancached", us_cached,
                     f"speedup_vs_default={us_default / us_cached:.1f}x"))
        rows.append((f"fig8_tpch_{name}_tuned", us_tuned,
                     f"speedup_vs_default={us_default / us_tuned:.1f}x"))
        rows.append((f"fig8_tpch_{name}_planner", us_planner,
                     f"speedup_vs_default={us_default / us_planner:.1f}x"))

    for name in ("q5", "q18"):   # Fig 9: the allocator case-study queries
        gain = (default_us[name] - tuned_us[name]) / default_us[name] * 100
        rows.append((f"fig9_tpch_{name}_alloc_default", default_us[name],
                     "untuned configuration"))
        rows.append((f"fig9_tpch_{name}_alloc_tuned", tuned_us[name],
                     f"latency_reduction={gain:.1f}%"))
    return rows
