"""Paper Figures 3/4 + Table 2: thread placement.

Fig 3 analogue: the topology-oblivious NONE layout vs affinitized
SPARSE/DENSE — quantified as ring-hop dilution of every collective (the
CPU-backend HLO is placement-invariant, so the topology model supplies the
hardware term; see DESIGN.md §2.2) plus a measured wall-time variance drill
of an UNPINNED vs PINNED reduction schedule.

Fig 4 analogue: Sparse vs Dense on an UNDERSUBSCRIBED mesh. TPU finding
(documented hardware adaptation): chips do not share memory controllers, so
contiguous (dense) subtori beat strided (sparse) placement — the paper's
Sparse>Dense holds only where neighbours share bandwidth; at full
subscription the two tie, exactly like the paper's plateau.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.config import MeshLayout
from repro.core.meshes import layout_device_order, axis_rings, mean_axis_hops
from repro.core.topology import (ICI_LINK_BW, TorusTopology,
                                 ring_allreduce_seconds, ring_neighbor_hops)


def _undersubscribed(topo: TorusTopology, n_active: int, strategy: str):
    """Device ids for an n_active-chip job placed dense (contiguous block)
    or sparse (strided across the torus)."""
    if strategy == "dense":
        return list(range(n_active))
    stride = topo.n_chips // n_active
    return list(range(0, topo.n_chips, stride))[:n_active]


def run() -> List[Row]:
    rows: List[Row] = []
    topo = TorusTopology(n_pods=1)
    nbytes = 64 << 20   # 64 MB gradient bucket

    # --- Fig 3 / Table 2: layout hop dilution -> modeled all-reduce time
    for layout in MeshLayout:
        hops_d = mean_axis_hops(layout, topo, "data")
        hops_m = mean_axis_hops(layout, topo, "model")
        order = layout_device_order(layout, topo)
        ring = axis_rings(order, 2)[0]
        t = ring_allreduce_seconds(nbytes, ring, topo)
        lar = 1.0 / max(hops_m, 1.0)   # local-access-ratio analogue
        rows.append((f"fig3_allreduce64MB_{layout.value}", t * 1e6,
                     f"hops_data={hops_d:.2f};hops_model={hops_m:.2f};"
                     f"LAR={lar:.2f}"))

    # --- Fig 4: sparse vs dense under 25/50/100% subscription
    for frac, n_active in ((0.25, 64), (0.5, 128), (1.0, 256)):
        for strat in ("dense", "sparse"):
            ring = _undersubscribed(topo, n_active, strat)
            t = ring_allreduce_seconds(nbytes, ring, topo)
            rows.append((f"fig4_{strat}_sub{int(frac*100)}pct", t * 1e6,
                         f"chips={n_active};hops={ring_neighbor_hops(topo, ring):.2f}"))
    return rows
