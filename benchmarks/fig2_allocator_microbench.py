"""Paper Figure 2: allocator scalability microbenchmark.

(a) execution throughput vs concurrent streams; (b) memory overhead ratio.
Reproduction target: the single-lock design (ptmalloc analogue) degrades
under concurrency; slab/arena scale; slab-family pays ~1.3x memory.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.config import AllocatorKind
from repro.memory.microbench import run_microbench


def run() -> List[Row]:
    rows: List[Row] = []
    for kind in AllocatorKind:
        for n in (1, 4, 16, 32):
            r = run_microbench(kind, n_streams=n, ops_per_stream=2000)
            us_per_op = 1e6 / r.ops_per_sec
            rows.append((
                f"fig2a_alloc_{kind.value}_streams{n}",
                us_per_op,
                f"ops/s={r.ops_per_sec:.0f};contention/op={r.contention_rate:.3f}"))
        rows.append((
            f"fig2b_overhead_{kind.value}",
            0.0,
            f"overhead_ratio={r.overhead_ratio:.3f}"))
    return rows
