"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment format). Modules:
  fig2   allocator microbenchmark (scalability + memory overhead)
  fig3/4 thread placement (layouts; sparse/dense undersubscription)
  fig5   placement policies x auto-rebalance (8-device mesh, measured)
  fig6   workload x allocator (device buffers + serving page pool)
  fig7   index nested-loop join (three index kinds)
  fig7_dist  distributed join: broadcast vs key-partitioned, plus the
         distributed TopK lowerings (replicated vs candidate-exchange)
         (8-dev mesh)
  fig8/9 TPC-H default vs tuned configuration
  fig_service  concurrent serving: QPS x p99 for ThreadPlacement x
         PlacementPolicy over a mixed Q1/Q3/Q6 open-loop workload
  fig_service_faults  degraded-mode serving: multi-tenant skewed-rate
         open-loop workload with a mid-run pool kill; per-class SLO and
         the degraded/healthy QPS ratio (absolute floor >= 0.50, gated
         whenever the module runs)
  fig_service_morsel  intra-query morsel parallelism: the same burst
         served whole-plan vs split-probe (build sides pool-replicated);
         QPS/p99 plus the split/whole ratio (absolute floor >= 0.15)
  fig_drift  estimator-drift summary: representative plans run under
         telemetry; reports drifting (node, stat) entries (absolute
         floor >= 1 — the detector must fire) and the max
         observed/estimated deviation ratio per Decision kind
  roofline  the dry-run (arch x shape x mesh) table
"""
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings to run")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip the subprocess-mesh figures")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows as JSON {name: us_per_call}, e.g. "
                         "BENCH_tpch.json for the perf trajectory")
    ap.add_argument("--check", default=None, metavar="PREV",
                    help="compare guarded rows against a previous --json "
                         "recording and exit non-zero on a >25%% latency "
                         "regression (makes the bench trajectory "
                         "enforceable in CI)")
    args = ap.parse_args()

    from benchmarks import (fig2_allocator_microbench,
                            fig3_fig4_thread_placement,
                            fig5_placement_policies,
                            fig6_workload_allocators, fig7_index_join,
                            fig8_fig9_tpch, fig_drift,
                            fig_service_throughput, roofline_table)
    from types import SimpleNamespace
    modules = [
        ("fig2", fig2_allocator_microbench),
        ("fig3_fig4", fig3_fig4_thread_placement),
        ("fig5", fig5_placement_policies),
        ("fig6", fig6_workload_allocators),
        ("fig7", fig7_index_join),
        ("fig7_dist", SimpleNamespace(run=fig7_index_join.run_dist)),
        ("fig8_fig9", fig8_fig9_tpch),
        ("fig_service", fig_service_throughput),
        ("fig_service_faults",
         SimpleNamespace(run=fig_service_throughput.run_faults)),
        ("fig_service_morsel",
         SimpleNamespace(run=fig_service_throughput.run_morsel)),
        ("fig_drift", fig_drift),
        ("roofline", roofline_table),
    ]
    if args.skip_slow:
        # the subprocess-mesh figures
        modules = [m for m in modules
                   if m[0] not in ("fig5", "fig7_dist", "fig_service")]
    if args.only:
        # a token that IS a module name selects exactly that module
        # (--only fig7 must not drag in the slow fig7_dist subprocess
        # sweep); other tokens keep substring semantics (--only fig3)
        names = {m[0] for m in modules}
        keys = args.only.split(",")
        modules = [m for m in modules
                   if any(k == m[0] if k in names else k in m[0]
                          for k in keys)]

    print("name,us_per_call,derived")
    failures = 0
    collected = {}
    for name, mod in modules:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
                collected[row_name] = us
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}_FAILED,0,{type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(collected, f, indent=2, sort_keys=True)
            f.write("\n")
    # absolute floors are checked WHENEVER their row was collected — no
    # baseline recording needed, so even a bootstrap CI run (no previous
    # --json) gates degraded-mode serving capacity
    floor_failed = check_floors(collected)
    if args.check and check_regression(collected, args.check):
        sys.exit(2)
    if floor_failed:
        sys.exit(2)
    sys.exit(1 if failures else 0)


# Rows whose latency the --check gate guards (the tuned-path trajectory).
CHECKED_ROWS = ("fig8_tpch_q1_tuned",)
CHECK_THRESHOLD = 1.25           # fail on >25% latency regression
# Rows whose value column is a THROUGHPUT (higher is better): the served
# Q1-mix QPS floor. A >25% QPS drop (collected < 0.75 * baseline) fails.
CHECKED_THROUGHPUT_ROWS = ("fig_service_q1mix_batched_qps",)
QPS_CHECK_THRESHOLD = 1.0 / 0.75
# Rows gated against an ABSOLUTE floor (no baseline needed): checked on
# every run that collects them. The degraded-QPS ratio asserts the
# service keeps >= 50% of healthy throughput after losing a pool; the
# drift-report row asserts the telemetry detector actually fires on the
# representative mis-estimated plans (a drift report is PRODUCED); the
# morsel ratio asserts split-probe serving keeps at least 15% of
# whole-plan throughput (best-of-3 bursts; it should GAIN on real
# multi-socket hardware, but the floor only has to catch a broken
# split path, not enforce speedup on an arbitrarily-loaded CI box
# whose single XLA threadpool serializes per-morsel dispatch).
CHECKED_FLOOR_ROWS = {"fig_service_degraded_qps_ratio": 0.50,
                      "fig_drift_report_rows": 1.0,
                      "fig_service_morsel_qps_ratio": 0.15}


def check_floors(collected: dict) -> bool:
    """True (-> non-zero exit) if any collected row sits below its floor."""
    failed = False
    for row, floor in CHECKED_FLOOR_ROWS.items():
        if row not in collected:
            continue
        ok = collected[row] >= floor
        print(f"check_{row},{collected[row]:.3f},"
              f"floor={floor:.2f} {'ok' if ok else 'BELOW_FLOOR'}")
        if not ok:
            failed = True
    return failed


def check_regression(collected: dict, prev_path: str) -> bool:
    """True (-> non-zero exit) if any guarded row regressed past threshold."""
    with open(prev_path) as f:
        prev = json.load(f)
    regressed = False
    checks = ([(r, CHECK_THRESHOLD, False) for r in CHECKED_ROWS]
              + [(r, QPS_CHECK_THRESHOLD, True)
                 for r in CHECKED_THROUGHPUT_ROWS])
    for row, threshold, is_qps in checks:
        if row not in collected:
            print(f"CHECK_SKIP,{row},not measured this run (check --only "
                  f"selection)", file=sys.stderr)
            continue
        if row not in prev:
            print(f"CHECK_SKIP,{row},not in {prev_path}", file=sys.stderr)
            continue
        # latency rows regress upward, throughput rows regress downward
        ratio = (prev[row] / collected[row] if is_qps
                 else collected[row] / prev[row])
        unit = "qps" if is_qps else "us"
        status = "REGRESSED" if ratio > threshold else "ok"
        print(f"check_{row},{collected[row]:.1f},"
              f"baseline={prev[row]:.1f}{unit} ratio={ratio:.2f}x {status}")
        if ratio > threshold:
            regressed = True
    return regressed


if __name__ == "__main__":
    main()
