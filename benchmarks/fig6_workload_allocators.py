"""Paper Figure 6: workload x allocator.

Two real measurements:
 (a) device workloads W1/W2/W3 wall time with the partition-buffer tuning
     the allocator implies (capacity factor = slack the allocator reserves;
     partition count = arena granularity) — the device-side analogue of
     "which allocator backs the hash tables";
 (b) the serving stack (continuous batching, paged KV) end-to-end with each
     HOST allocator backing the page pool — tokens/s + admission stalls +
     page-manager contention. This is where ptmalloc-vs-tbbmalloc shows up
     on a TPU system for real.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.analytics.aggregate import count_partitioned, median_jit
from repro.analytics.datasets import blanas_join, moving_cluster
from repro.analytics.join import hash_join
from repro.core.config import AllocatorKind


def run() -> List[Row]:
    rows: List[Row] = []
    G, N = 4096, 1 << 19
    ds = moving_cluster(N, G, seed=1)
    keys = jnp.asarray(ds.keys)
    vals = jnp.asarray(ds.vals)

    # (a) device-side buffer tuning (bump=tight serial, slab=sized classes)
    tunings = {"bump_like": dict(n_partitions=1, capacity_factor=1.05),
               "arena_like": dict(n_partitions=16, capacity_factor=1.5),
               "slab_like": dict(n_partitions=64, capacity_factor=2.0)}
    for name, kw in tunings.items():
        us = time_fn(lambda kw=kw: count_partitioned(keys, G, mode="ref",
                                                     **kw))
        rows.append((f"fig6_w2_{name}", us, str(kw)))
    us = time_fn(lambda: median_jit(keys, vals, G))
    rows.append(("fig6_w1_sort_median", us, f"N={N};G={G}"))

    jd = blanas_join(1 << 14, 1 << 17, seed=2)
    bk, bv, pk = (jnp.asarray(jd.build_keys), jnp.asarray(jd.build_vals),
                  jnp.asarray(jd.probe_keys))
    for name, nparts in (("arena_like", 32), ("slab_like", 128)):
        kw = dict(n_partitions=nparts, capacity_factor=2.0)
        us = time_fn(lambda kw=kw: hash_join(bk, bv, pk, mode="ref", **kw))
        rows.append((f"fig6_w3_{name}", us, str(kw)))

    # (b) serving with each host allocator backing the KV page pool
    from repro.configs.reduced import REDUCED
    from repro.core.params import init_params
    from repro.models.lm import LMModel
    from repro.runtime import ContinuousBatcher, Request
    arch = REDUCED["qwen2-0.5b"]
    model = LMModel(arch, tp=1, remat="none")
    params = init_params(model.schema(), jax.random.PRNGKey(0), jnp.float32)
    import time as _time
    for kind in AllocatorKind:
        b = ContinuousBatcher(model, params, wave_slots=8, max_len=64,
                              page_tokens=8, n_pages=48, allocator=kind)
        for i in range(24):
            b.submit(Request(req_id=i, prompt_len=6, max_new_tokens=8))
        t0 = _time.perf_counter()
        stats = b.run(max_steps=600)
        dt = _time.perf_counter() - t0
        st = b.kv.allocator_stats
        rows.append((f"fig6_serve_{kind.value}", dt * 1e6 / max(stats.steps, 1),
                     f"tokens/s={stats.tokens_out/dt:.0f};"
                     f"stalls={stats.admission_stalls};"
                     f"contention={st.contentions};"
                     f"util={stats.lane_utilization:.2f}"))
    return rows
