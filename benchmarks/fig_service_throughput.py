"""Service throughput: QPS x p99 under a mixed open-loop TPC-H workload.

The serving-layer figure the paper implies but never draws: co-running
analytic queries (Awan et al.'s throughput-collapse scenario) served
through the concurrent subsystem, swept over the two placement axes —

  ThreadPlacement   OS_DEFAULT / DENSE / SPARSE pool affinity
                    (the Fig 3/4 thread-placement strategies)
  PlacementPolicy   local (no mesh) / FIRST_TOUCH / INTERLEAVE memory
                    placement on a 4-device mesh (the Fig 5 policies)

for a mixed Q1/Q3/Q6 open-loop burst. Plus the multi-query batching
payoff on the plan-cache-hot path: the same Q1 asked 32 times serves as
ONE deduplicated dispatch vs 32 one-at-a-time dispatches — the
``fig_service_q1mix_batched_qps`` row is guarded by ``run.py --check``'s
throughput floor (>25% QPS regression fails CI).

Runs in a 4-fake-device subprocess (like fig5) so the mesh policies are
real shard_map executions.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, run_in_mesh

CODE = """
import json, time, jax
from repro.analytics.planner import ExecutionContext
from repro.analytics.service import AnalyticsService, ServiceConfig, ThreadPlacement
from repro.analytics.tpch import LOGICAL_QUERIES, generate, run_query, submit_query
from repro.core.config import PlacementPolicy

data = generate(scale=0.004, seed=0)
tables = data.as_jax()
mesh = jax.make_mesh((4,), ("data",))
MIX = ("q1", "q3", "q6")
N_MIX = 18

contexts = {
    "local": ExecutionContext(executor="cost"),
    "first_touch": ExecutionContext(executor="cost", mesh=mesh,
                                    policy=PlacementPolicy.FIRST_TOUCH),
    "interleave": ExecutionContext(executor="cost", mesh=mesh,
                                   policy=PlacementPolicy.INTERLEAVE),
}

# warm the plan cache: the grid measures the serving layer, not compiles
for ctx in contexts.values():
    for q in MIX:
        run_query(q, data, context=ctx)
with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                    morsel_rows=2000)) as warm:
    for q in MIX:       # the morsel-split executables compile here too
        submit_query(warm, q, data, context=contexts["local"])
    warm.drain()

res = {}
for placement in ThreadPlacement:
    for pol_name, ctx in contexts.items():
        # batching=False: the grid measures the PLACEMENT axis, so all 18
        # requests must contend across pools as distinct tasks — batched
        # they would dedup to 3 dispatches (that axis is measured below)
        svc = AnalyticsService(ServiceConfig(
            n_pools=2, workers_per_pool=2, placement=placement,
            batching=False,
            morsel_rows=2000 if pol_name == "local" else None))
        t0 = time.perf_counter()
        for i in range(N_MIX):
            submit_query(svc, MIX[i % len(MIX)], data, context=ctx)
        svc.drain()
        elapsed = time.perf_counter() - t0
        st = svc.stats()
        svc.close()
        res[f"mix_{placement.value}_{pol_name}"] = {
            "us": elapsed / N_MIX * 1e6, "qps": N_MIX / elapsed,
            "p99_ms": st.latency_p99_ms, "steals": st.steals,
            "morsels": st.morsels}

# batching payoff on the plan-cache-hot path: 32x the same Q1. The
# guarded QPS row must be stable enough to gate at 25%: a single batched
# drain is ~ms-scale and jitters wildly, so take the MEDIAN of 9 rounds
# (the same discipline as the fig8 tuned-latency gate, time_fn iters=9).
N_HOT, ROUNDS = 32, 9
run_query("q1", data, context=contexts["local"])
for batching, tag in ((False, "serial"), (True, "batched")):
    svc = AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                         batching=batching))
    elapsed = []
    for _ in range(ROUNDS):
        t0 = time.perf_counter()
        for _ in range(N_HOT):
            submit_query(svc, "q1", data, context=contexts["local"])
        svc.drain()
        elapsed.append(time.perf_counter() - t0)
    st = svc.stats()
    svc.close()
    med = sorted(elapsed)[len(elapsed) // 2]
    res[f"q1mix_{tag}"] = {"us": med / N_HOT * 1e6,
                           "qps": N_HOT / med,
                           "dispatches": st.dispatches,
                           "p99_ms": st.latency_p99_ms}
res["q1mix_speedup"] = res["q1mix_serial"]["us"] / res["q1mix_batched"]["us"]
print(json.dumps(res))
"""


def run() -> List[Row]:
    res = run_in_mesh(CODE, n_devices=4, timeout=1800)
    rows: List[Row] = []
    speedup = res.pop("q1mix_speedup")
    for name, d in res.items():
        derived = ";".join(f"{k}={v:.2f}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in d.items()
                           if k != "us")
        if name == "q1mix_batched":
            derived += f";batching_speedup={speedup:.2f}x"
        rows.append((f"fig_service_{name}", d["us"], derived))
    # the throughput-floor row: the value column carries QPS (not us) so
    # run.py --check can gate on a >25% QPS regression directly
    rows.append(("fig_service_q1mix_batched_qps",
                 res["q1mix_batched"]["qps"],
                 f"queries_per_sec;guarded_by=--check;"
                 f"batching_speedup={speedup:.2f}x"))
    return rows
