"""Service throughput: QPS x p99 under a mixed open-loop TPC-H workload.

The serving-layer figure the paper implies but never draws: co-running
analytic queries (Awan et al.'s throughput-collapse scenario) served
through the concurrent subsystem, swept over the two placement axes —

  ThreadPlacement   OS_DEFAULT / DENSE / SPARSE pool affinity
                    (the Fig 3/4 thread-placement strategies)
  PlacementPolicy   local (no mesh) / FIRST_TOUCH / INTERLEAVE memory
                    placement on a 4-device mesh (the Fig 5 policies)

for a mixed Q1/Q3/Q6 open-loop burst. Plus the multi-query batching
payoff on the plan-cache-hot path: the same Q1 asked 32 times serves as
ONE deduplicated dispatch vs 32 one-at-a-time dispatches — the
``fig_service_q1mix_batched_qps`` row is guarded by ``run.py --check``'s
throughput floor (>25% QPS regression fails CI).

Runs in a 4-fake-device subprocess (like fig5) so the mesh policies are
real shard_map executions.

``run_faults`` (registered as the ``fig_service_faults`` module) is the
degraded-mode companion: an open-loop multi-tenant skewed-rate workload
with a mid-run pool kill, reporting per-class SLO attainment and the
``fig_service_degraded_qps_ratio`` row gated by run.py's absolute floor
(degraded QPS >= 50% of healthy). It runs in-process so the default CI
sweep (--skip-slow) exercises it.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, run_in_mesh

CODE = """
import json, time, jax
from repro.analytics.planner import ExecutionContext
from repro.analytics.service import AnalyticsService, ServiceConfig, ThreadPlacement
from repro.analytics.tpch import LOGICAL_QUERIES, generate, run_query, submit_query
from repro.core.config import PlacementPolicy

data = generate(scale=0.004, seed=0)
tables = data.as_jax()
mesh = jax.make_mesh((4,), ("data",))
MIX = ("q1", "q3", "q6")
N_MIX = 18

contexts = {
    "local": ExecutionContext(executor="cost"),
    "first_touch": ExecutionContext(executor="cost", mesh=mesh,
                                    policy=PlacementPolicy.FIRST_TOUCH),
    "interleave": ExecutionContext(executor="cost", mesh=mesh,
                                   policy=PlacementPolicy.INTERLEAVE),
}

# warm the plan cache: the grid measures the serving layer, not compiles
for ctx in contexts.values():
    for q in MIX:
        run_query(q, data, context=ctx)
with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                    morsel_rows=2000)) as warm:
    for q in MIX:       # the morsel-split executables compile here too
        submit_query(warm, q, data, context=contexts["local"])
    warm.drain()

res = {}
for placement in ThreadPlacement:
    for pol_name, ctx in contexts.items():
        # batching=False: the grid measures the PLACEMENT axis, so all 18
        # requests must contend across pools as distinct tasks — batched
        # they would dedup to 3 dispatches (that axis is measured below)
        svc = AnalyticsService(ServiceConfig(
            n_pools=2, workers_per_pool=2, placement=placement,
            batching=False,
            morsel_rows=2000 if pol_name == "local" else None))
        t0 = time.perf_counter()
        for i in range(N_MIX):
            submit_query(svc, MIX[i % len(MIX)], data, context=ctx)
        svc.drain()
        elapsed = time.perf_counter() - t0
        st = svc.stats()
        svc.close()
        res[f"mix_{placement.value}_{pol_name}"] = {
            "us": elapsed / N_MIX * 1e6, "qps": N_MIX / elapsed,
            "p99_ms": st.latency_p99_ms, "steals": st.steals,
            "morsels": st.morsels}

# batching payoff on the plan-cache-hot path: 32x the same Q1. The
# guarded QPS row must be stable enough to gate at 25%: a single batched
# drain is ~ms-scale and jitters wildly, so take the MEDIAN of 9 rounds
# (the same discipline as the fig8 tuned-latency gate, time_fn iters=9).
N_HOT, ROUNDS = 32, 9
run_query("q1", data, context=contexts["local"])
for batching, tag in ((False, "serial"), (True, "batched")):
    svc = AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                         batching=batching))
    elapsed = []
    for _ in range(ROUNDS):
        t0 = time.perf_counter()
        for _ in range(N_HOT):
            submit_query(svc, "q1", data, context=contexts["local"])
        svc.drain()
        elapsed.append(time.perf_counter() - t0)
    st = svc.stats()
    svc.close()
    med = sorted(elapsed)[len(elapsed) // 2]
    res[f"q1mix_{tag}"] = {"us": med / N_HOT * 1e6,
                           "qps": N_HOT / med,
                           "dispatches": st.dispatches,
                           "p99_ms": st.latency_p99_ms}
res["q1mix_speedup"] = res["q1mix_serial"]["us"] / res["q1mix_batched"]["us"]
print(json.dumps(res))
"""


def run_faults() -> List[Row]:
    """Degraded-mode serving: an open-loop multi-tenant skewed-rate
    workload (three priority classes, per-class deadlines) served by the
    ALWAYS-ON loop, healthy vs with pool 1 killed ~40% of the way
    through. Emits per-class SLO attainment for the degraded run and the
    ``fig_service_degraded_qps_ratio`` row that run.py gates against an
    absolute floor (degraded >= 50% of healthy QPS) whenever the module
    runs — no baseline recording needed. In-process (no mesh subprocess):
    it must run in the default CI sweep, which skips subprocess figures."""
    import time

    from repro.analytics.planner import ExecutionContext
    from repro.analytics.service import (AnalyticsService, RetryPolicy,
                                         ServiceConfig, ServiceFaultInjector)
    from repro.analytics.tpch import generate, run_query, submit_query

    data = generate(scale=0.004, seed=0)
    ctx = ExecutionContext(executor="cost")
    mix = ("q1", "q3", "q6")
    for q in mix:
        run_query(q, data, context=ctx)          # measure serving, not jit

    # open-loop arrival schedule: three tenants with SKEWED rates — an
    # interactive class outpacing a mid class outpacing a batch flood —
    # each with its own deadline budget; identical schedule both runs
    tenants = [              # (client_id, priority, rate_qps, deadline_s)
        (0, 2, 45.0, 0.6), (1, 1, 25.0, 1.0), (2, 0, 15.0, 2.0)]
    horizon_s = 1.2
    sched = sorted(
        (k / rate, cid, prio, dl)
        for cid, prio, rate, dl in tenants
        for k in range(int(rate * horizon_s)))
    n_total = len(sched)

    def one_run(faults):
        svc = AnalyticsService(ServiceConfig(
            n_pools=2, workers_per_pool=2, batching=False, queue_depth=512,
            faults=faults,
            retry=RetryPolicy(max_attempts=3, base_backoff_s=0.002,
                              max_backoff_s=0.02)))
        svc.start()
        t0 = time.perf_counter()
        for off, cid, prio, dl in sched:
            lag = off - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            submit_query(svc, mix[(cid + int(off * 997)) % len(mix)], data,
                         context=ctx, client_id=cid, priority=prio,
                         deadline_s=dl)
        svc.drain(timeout=120.0)
        elapsed = time.perf_counter() - t0
        st = svc.stats()
        svc.close()
        return st, elapsed

    healthy, t_h = one_run(None)
    kill_at = int(n_total * 0.4)                 # mid-workload pool loss
    degraded, t_d = one_run(
        ServiceFaultInjector(seed=0, kill_pool_at=(kill_at, 1)))
    qps_h = healthy.completed / t_h
    qps_d = degraded.completed / t_d
    ratio = qps_d / qps_h if qps_h > 0 else 0.0

    rows: List[Row] = [
        ("fig_service_faults_healthy_qps", qps_h,
         f"queries_per_sec;completed={healthy.completed}/{n_total};"
         f"p99_ms={healthy.latency_p99_ms:.2f}"),
        ("fig_service_faults_degraded_qps", qps_d,
         f"queries_per_sec;pool1_killed_at_dispatch={kill_at};"
         f"completed={degraded.completed}/{n_total};"
         f"requeued={degraded.requeued};retries={degraded.retries};"
         f"p99_ms={degraded.latency_p99_ms:.2f}"),
        ("fig_service_degraded_qps_ratio", ratio,
         "degraded_over_healthy_qps;floor=0.50;guarded_whenever_run"),
        # p99 latency DECOMPOSED by serving phase (the tracing PR's
        # attribution): value = degraded execute p99; the detail column
        # carries the full breakdown so a p99 regression is attributable
        # to queue wait vs retry backoff vs execute without re-running
        ("fig_service_faults_p99_breakdown",
         degraded.phase_p99_ms.get("execute", 0.0),
         "execute_p99_ms;" + ";".join(
             f"{k}_p99_ms={v:.2f}"
             for k, v in degraded.phase_p99_ms.items())),
    ]
    for prio in sorted(degraded.per_class):
        cs = degraded.per_class[prio]
        rows.append((f"fig_service_faults_slo_class{prio}",
                     cs.slo_attainment,
                     f"slo_attainment;admitted={cs.admitted};"
                     f"completed={cs.completed};expired={cs.expired};"
                     f"shed={cs.shed};retries={cs.retries}"))
    return rows


def run_morsel() -> List[Row]:
    """Intra-query morsel parallelism payoff (registered as the
    ``fig_service_morsel`` module): the same q3/q5 burst served twice —
    once with the split-probe path DISABLED (morsel_split_rows pinned
    above every probe, each request one whole-plan dispatch) and once
    with the default threshold (probe sides split into per-pool morsels,
    build sides pool-replicated). Both paths are bit-identical by
    construction, so the figure is purely QPS/p99; the
    ``fig_service_morsel_qps_ratio`` row (split/whole) is gated by
    run.py's absolute floor — split-probe dispatch overhead must never
    cost more than it parallelizes. In-process so the default CI sweep
    exercises it."""
    import dataclasses
    import time

    from repro.analytics import planner
    from repro.analytics.planner import ExecutionContext
    from repro.analytics.service import AnalyticsService, ServiceConfig
    from repro.analytics.tpch import generate, submit_query

    data = generate(scale=0.004, seed=0)
    ctx = ExecutionContext(executor="cost")
    mix = ("q3", "q5")
    n_req = 16
    base = planner.current_cost_profile()
    res = {}
    try:
        for tag, profile in (
                ("whole", dataclasses.replace(base,
                                              morsel_split_rows=1 << 30)),
                ("split", base)):
            planner.set_cost_profile(profile)
            svc = AnalyticsService(ServiceConfig(
                n_pools=2, workers_per_pool=2, batching=False,
                morsel_rows=2000))
            for q in mix:                        # warm jits untimed
                submit_query(svc, q, data, context=ctx)
            svc.drain()
            # best-of-3 bursts: per-morsel dispatch timing is noisy on a
            # shared CPU (steal storms, jit dispatch contention), and the
            # gated ratio should compare CAPABILITY, not one bad draw
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                for i in range(n_req):
                    submit_query(svc, mix[i % len(mix)], data, context=ctx)
                svc.drain()
                best = min(best, time.perf_counter() - t0)
            elapsed = best
            st = svc.stats()
            svc.close()
            res[tag] = {"us": elapsed / n_req * 1e6,
                        "qps": n_req / elapsed,
                        "p99_ms": st.latency_p99_ms,
                        "morsels": st.morsels, "steals": st.steals}
    finally:
        planner.set_cost_profile(base)
    # the split run must actually have split: more morsels than requests
    assert res["split"]["morsels"] > res["whole"]["morsels"], res
    rows: List[Row] = []
    for tag in ("whole", "split"):
        d = res[tag]
        rows.append((f"fig_service_morsel_{tag}", d["us"],
                     f"qps={d['qps']:.2f};p99_ms={d['p99_ms']:.2f};"
                     f"morsels={d['morsels']};steals={d['steals']}"))
    rows.append(("fig_service_morsel_qps_ratio",
                 res["split"]["qps"] / res["whole"]["qps"],
                 "split_over_whole_qps;floor=0.15;guarded_whenever_run"))
    return rows


def run() -> List[Row]:
    res = run_in_mesh(CODE, n_devices=4, timeout=1800)
    rows: List[Row] = []
    speedup = res.pop("q1mix_speedup")
    for name, d in res.items():
        derived = ";".join(f"{k}={v:.2f}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in d.items()
                           if k != "us")
        if name == "q1mix_batched":
            derived += f";batching_speedup={speedup:.2f}x"
        rows.append((f"fig_service_{name}", d["us"], derived))
    # the throughput-floor row: the value column carries QPS (not us) so
    # run.py --check can gate on a >25% QPS regression directly
    rows.append(("fig_service_q1mix_batched_qps",
                 res["q1mix_batched"]["qps"],
                 f"queries_per_sec;guarded_by=--check;"
                 f"batching_speedup={speedup:.2f}x"))
    return rows
