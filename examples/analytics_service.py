"""Serving a mixed analytics workload through the concurrent service.

Three clients submit a mix of TPC-H queries — same tables, different
plans, priorities, one with a distributed placement-policy context —
into one ALWAYS-ON AnalyticsService (background drain loop serving
while admission continues). The admission queue bounds intake with
priority classes, the batcher collapses structurally identical requests
into single dispatches, and the morsel scheduler spreads row-range
morsels over socket-pinned worker pools under a ThreadPlacement
strategy (work steals counted). Served results are the planner's own
compiled plans: the whole-plan path is bit-identical to calling
run_query yourself.

The tail of the example is a fault drill: a seeded ServiceFaultInjector
kills worker pool 1 mid-round and fails one dispatch build — the
service retries the build, requeues the dead pool's backlog, and keeps
serving on the survivor (same results, counters tell the story).

    PYTHONPATH=src python examples/analytics_service.py
(re-executes itself with 8 fake devices)
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

if "XLA_FLAGS" not in os.environ:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    sys.exit(subprocess.run([sys.executable, __file__], env=env).returncode)

sys.path.insert(0, SRC)

import jax
import numpy as np

from repro.analytics.planner import ExecutionContext
from repro.analytics.service import (AnalyticsService, ServiceConfig,
                                     ThreadPlacement)
from repro.analytics.tpch import generate, run_query, submit_query
from repro.core.config import PlacementPolicy

data = generate(scale=0.01, seed=7)
local = ExecutionContext(executor="cost")
mesh = jax.make_mesh((8,), ("data",))
dist = ExecutionContext(executor="cost", mesh=mesh,
                        policy=PlacementPolicy.INTERLEAVE)

service = AnalyticsService(ServiceConfig(
    n_pools=2, workers_per_pool=2, queue_depth=64,
    morsel_rows=8000,                       # split big scans into morsels
    placement=ThreadPlacement.SPARSE))      # stripe morsels across pools
service.start()                             # always-on background drain

# an open-loop burst from three clients: dashboards hammering Q1 (the
# interactive class), an analyst running the join-heavy Q3/Q5, a
# distributed Q18 on the mesh — admitted WHILE the loop serves
rids = {}
for i in range(8):
    rids[f"dash-{i}"] = submit_query(service, "q1", data, context=local,
                                     client_id=0, priority=2)
for i, name in enumerate(("q3", "q5", "q6")):
    rids[f"analyst-{name}"] = submit_query(service, name, data,
                                           context=local, client_id=1,
                                           priority=1)
rids["mesh-q18"] = submit_query(service, "q18", data, context=dist,
                                client_id=2, priority=0)

results = service.drain(timeout=300.0)      # wait for quiescence
stats = service.stats()

print("served", stats.completed, "queries:", stats.describe())
print(f"  batching: {stats.dispatches} dispatches for {stats.completed} "
      f"queries ({stats.dedup_hits} dedup hits)")
print(f"  morsels: {stats.morsels} dispatched, steals/pool = "
      f"{list(stats.steals_per_pool)}")
print(f"  queue wait p50/p99: {stats.queue_wait_p50_ms:.2f}/"
      f"{stats.queue_wait_p99_ms:.2f} ms")

# the whole-plan served result is bit-identical to serial execution
ref = run_query("q18", data, context=dist)
got = results[rids["mesh-q18"]].value
err = max(np.abs(np.asarray(got[k]) - np.asarray(ref[k])).max()
          for k in ref)
print(f"\nserved q18 vs serial run_query: max |diff| = {err} "
      "(same compiled plan, same inputs)")
service.stop()

# --- fault drill: kill a pool mid-round + fail a build, keep serving ---
from repro.analytics.service import RetryPolicy, ServiceFaultInjector

faults = ServiceFaultInjector(seed=0, build_fail_at={0},
                              kill_pool_at=(2, 1))
drill = AnalyticsService(ServiceConfig(
    n_pools=2, workers_per_pool=2, batching=False, faults=faults,
    retry=RetryPolicy(max_attempts=3, base_backoff_s=0.005)))
drill_rids = [submit_query(drill, q, data, context=local)
              for q in ("q1", "q3", "q6", "q1", "q6")]
drill_res = drill.drain()
dst = drill.stats()
drill.close()
ref_q1 = run_query("q1", data, context=local)
same = all(np.array_equal(np.asarray(drill_res[drill_rids[0]].value[k]),
                          np.asarray(ref_q1[k])) for k in ref_q1)
print(f"\nfault drill: build_failures={faults.builds_failed} "
      f"pool_kills={faults.pools_killed} -> retries={dst.retries}, "
      f"dead_pools={list(dst.dead_pools)}, requeued={dst.requeued}, "
      f"completed={dst.completed}/{len(drill_rids)} "
      f"(bit-identical={same})")
