"""Quickstart: the paper's thesis in 60 seconds on CPU.

Runs W1 (holistic aggregation) + W3 (hash join) single-device, then shows
the four placement policies producing identical answers with different
communication plans, and a reduced-LM train step — all through the same
application-agnostic knobs.

    PYTHONPATH=src python examples/quickstart.py
"""
import subprocess
import sys
import os

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analytics.aggregate import median_jit
from repro.analytics.datasets import blanas_join, moving_cluster
from repro.analytics.join import hash_join
from repro.configs.reduced import REDUCED
from repro.core.config import LM_SHAPES, RunConfig, TrainConfig
from repro.models.lm import LMModel
from repro.runtime import train


def main():
    print("== W1: holistic aggregation (GROUP BY median) ==")
    ds = moving_cluster(200_000, 4096, seed=0)
    med = median_jit(jnp.asarray(ds.keys), jnp.asarray(ds.vals), 4096)
    print(f"   groups: {int(jnp.sum(~jnp.isnan(med)))}/4096, "
          f"median[0]={float(med[0]):.4f}")

    print("== W3: hash join (1:16 PK-FK) ==")
    jd = blanas_join(65_536, 1_048_576, seed=1)
    cnt, chk, ovf = hash_join(jnp.asarray(jd.build_keys),
                              jnp.asarray(jd.build_vals),
                              jnp.asarray(jd.probe_keys),
                              n_partitions=64, mode="ref")
    print(f"   matches: {int(cnt)}, checksum: {float(chk):.1f}, "
          f"overflow: {int(ovf)}")

    print("== placement policies (8-device subprocess mesh) ==")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.config import PlacementPolicy
from repro.analytics.engine import dist_count
from repro.analytics.datasets import zipf
mesh = jax.make_mesh((8,), ("data",))
ds = zipf(65536, 64, seed=2)
keys = jnp.asarray(ds.keys)
for pol in PlacementPolicy:
    out = jax.jit(dist_count(mesh, pol, 64))(keys)
    print(f"   {pol.value:12s} total={float(out.sum()):.0f}")
"""
    subprocess.run([sys.executable, "-c", code], env=env, check=True)

    print("== reduced-LM train step (qwen2-family) ==")
    arch = REDUCED["qwen2-0.5b"]
    model = LMModel(arch, tp=1, remat="none")
    cfg = RunConfig(arch=arch, shape=LM_SHAPES["train_4k"],
                    train=TrainConfig(learning_rate=3e-3, warmup_steps=2))
    res = train(model, cfg, n_steps=6, batch=4, seq=32)
    print(f"   loss: {res.losses[0]:.3f} -> {res.final_loss:.3f}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
