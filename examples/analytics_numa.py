"""The paper's experiment grid on a multi-device mesh: W1/W2/W3 under all
four memory placement policies + the AutoNUMA analogue, with wall times —
a miniature of paper Figures 5/6.

    PYTHONPATH=src python examples/analytics_numa.py
(re-executes itself with 8 fake devices)
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

if "XLA_FLAGS" not in os.environ:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    sys.exit(subprocess.run([sys.executable, __file__], env=env).returncode)

sys.path.insert(0, SRC)

import time
import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.datasets import blanas_join, moving_cluster
from repro.analytics.engine import dist_count, dist_hash_join, dist_median
from repro.core.config import PlacementPolicy


def bench(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e3


def main():
    mesh = jax.make_mesh((8,), ("data",))
    G, N = 4096, 1 << 20
    ds = moving_cluster(N, G, seed=3)
    keys, vals = jnp.asarray(ds.keys), jnp.asarray(ds.vals)
    jd = blanas_join(1 << 15, 1 << 18, seed=4)
    bk, bv, pk = map(jnp.asarray, (jd.build_keys, jd.build_vals,
                                   jd.probe_keys))

    print(f"{'policy':14s} {'W1 median':>12s} {'W2 count':>12s} "
          f"{'W3 join':>12s}")
    for pol in PlacementPolicy:
        w1 = bench(jax.jit(dist_median(mesh, pol, G)), keys, vals)
        w2 = bench(jax.jit(dist_count(mesh, pol, G)), keys)
        w3 = bench(jax.jit(dist_hash_join(mesh, pol)), bk, bv, pk)
        print(f"{pol.value:14s} {w1:10.1f}ms {w2:10.1f}ms {w3:10.1f}ms")

    # AutoNUMA analogue on the default policy
    w2_auto = bench(jax.jit(dist_count(mesh, PlacementPolicy.FIRST_TOUCH, G,
                                       auto_rebalance=True)), keys)
    print(f"{'first+autoNUMA':14s} {'':>12s} {w2_auto:10.1f}ms")
    print("\npaper finding reproduced: INTERLEAVE wins where state is truly "
          "shared (W1 holistic); local-then-merge suffices for W2 (Fig 6h).")


main()
