"""One user-authored logical plan, many execution strategies.

The paper's thesis — NUMA tuning applies without rewriting the application
— as an API: a query is authored ONCE against the logical plan IR
(repro.analytics.plan) and handed to the cost-based physical planner
(repro.analytics.planner), which changes the execution strategy through
the ExecutionContext alone: naive XLA plan, cost-chosen fused kernels, or
a distributed placement-policy backend on a device mesh.

    PYTHONPATH=src python examples/analytics_plan.py
(re-executes itself with 8 fake devices)
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

if "XLA_FLAGS" not in os.environ:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    sys.exit(subprocess.run([sys.executable, __file__], env=env).returncode)

sys.path.insert(0, SRC)

import jax
import numpy as np

from repro.analytics.plan import LogicalPlan, col, describe, scan
from repro.analytics.planner import ExecutionContext, execute_plan, explain
from repro.analytics.tpch import generate
from repro.core.config import PlacementPolicy

# A query that is NOT one of the five shipped TPC-H builders: revenue and
# order count per customer nation for heavily-discounted recent lineitems.
li = scan("lineitem").filter((col("l_discount") >= 0.05)
                             & (col("l_shipdate") > 1800))
li = li.join(scan("orders"), "l_orderkey", "o_orderkey",
             {"_cust": "o_custkey"})
li = li.join(scan("customer"), "_cust", "c_custkey",
             {"_nation": "c_nationkey"})
li = li.project(_rev=col("l_extendedprice") * (1 - col("l_discount")))
plan = LogicalPlan(
    li.aggregate("_nation", 25, revenue=("sum", "_rev"),
                 avg_rev=("avg", "_rev"), orders=("count", "_rev")),
    ("revenue", "avg_rev", "orders", "_overflow"))

print("logical plan:")
print(describe(plan))

tables = generate(scale=0.01, seed=7).as_jax()

# Context 1: single device, cost-based physical choices.
local = ExecutionContext(executor="cost")
print("\nplanner decisions (local, cost-based):")
for d in explain(plan, tables, local):
    print(" ", d.describe())
out_local = execute_plan(plan, tables, local)

# Context 2: SAME plan on an 8-device mesh under a placement policy.
mesh = jax.make_mesh((8,), ("data",))
dist = ExecutionContext(executor="cost", mesh=mesh,
                        policy=PlacementPolicy.INTERLEAVE)
out_dist = execute_plan(plan, tables, dist)

print("\nrevenue by nation (local cost-based):")
print(np.array2string(np.asarray(out_local["revenue"]), precision=0))
print("revenue by nation (8-device mesh, INTERLEAVE policy):")
print(np.array2string(np.asarray(out_dist["revenue"]), precision=0))
err = np.abs(np.asarray(out_local["revenue"])
             - np.asarray(out_dist["revenue"])).max()
print(f"\nmax |local - distributed| = {err:.3g} "
      "(same logical plan, two execution strategies)")
