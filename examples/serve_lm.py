"""Serving example: continuous batching with the paged KV cache under the
THP (page size) and allocator knobs — paper Section 3.4.1 live.

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import REDUCED
from repro.core.config import AllocatorKind
from repro.core.params import init_params
from repro.models.lm import LMModel
from repro.runtime import ContinuousBatcher, Request


def serve(page_tokens, allocator):
    arch = REDUCED["qwen2-0.5b"]
    model = LMModel(arch, tp=1, remat="none")
    params = init_params(model.schema(), jax.random.PRNGKey(0), jnp.float32)
    b = ContinuousBatcher(model, params, wave_slots=8, max_len=96,
                          page_tokens=page_tokens, n_pages=64,
                          allocator=allocator)
    rng = np.random.RandomState(0)
    for i in range(32):
        b.submit(Request(req_id=i, prompt_len=int(rng.randint(4, 24)),
                         max_new_tokens=12))
    t0 = time.perf_counter()
    stats = b.run(max_steps=2000)
    dt = time.perf_counter() - t0
    return stats, dt


def main():
    print(f"{'page_tokens':>11s} {'allocator':>9s} {'tok/s':>8s} "
          f"{'frag':>6s} {'stalls':>6s} {'util':>5s}")
    for page_tokens in (8, 32):           # THP: small vs huge pages
        for alloc in (AllocatorKind.BUMP, AllocatorKind.SLAB):
            stats, dt = serve(page_tokens, alloc)
            print(f"{page_tokens:11d} {alloc.value:>9s} "
                  f"{stats.tokens_out/dt:8.0f} "
                  f"{stats.fragmentation:6.2f} {stats.admission_stalls:6d} "
                  f"{stats.lane_utilization:5.2f}")
    print("\nsmall pages: low fragmentation, more allocator traffic; "
          "large pages invert it — paper 3.4.1 on a TPU serving stack.")


if __name__ == "__main__":
    main()
