"""End-to-end driver (assignment deliverable b): train a reduced LM for a
few hundred steps on CPU with checkpointing + a failure drill mid-run.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-0.5b --steps 200
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_arch
from repro.configs.reduced import reduced
from repro.core.config import LM_SHAPES, RunConfig, TrainConfig
from repro.models.lm import LMModel
from repro.runtime import FailureInjector, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    arch = reduced(get_arch(args.arch))
    model = LMModel(arch, tp=1, remat="block")
    cfg = RunConfig(arch=arch, shape=LM_SHAPES["train_4k"],
                    train=TrainConfig(learning_rate=1e-3,
                                      warmup_steps=args.steps // 10))
    with tempfile.TemporaryDirectory() as ckpt:
        res = train(model, cfg, n_steps=args.steps, batch=args.batch,
                    seq=args.seq, ckpt_dir=ckpt, ckpt_every=25,
                    injector=FailureInjector(
                        fail_at_steps=[args.steps // 2]))
        print(f"arch={arch.name} steps={res.steps_run} "
              f"restarts={res.restarts}")
        k = max(1, len(res.losses) // 10)
        for i in range(0, len(res.losses), k):
            print(f"  step {i:4d}  loss {res.losses[i]:.4f}")
        print(f"  final loss {res.final_loss:.4f} "
              f"(start {res.losses[0]:.4f})")
        assert res.final_loss < res.losses[0]


if __name__ == "__main__":
    main()
