"""Calibrate the planner's cost constants from measured microbenchmarks.

The cost model (planner.aggregate_costs) expresses every physical
Aggregate layout in *pass-equivalents* over the input rows, with three
hand-set constants: FUSED_FIXED (fused sweep setup), FUSED_PER_COL
(marginal cost per stacked column), SORT_PASS_FACTOR (argsort passes per
log2 n). This script measures them on the CURRENT backend:

  1. one-pass baseline: t_xla(C) — the XLA layout runs one segment op per
     stacked column, so its slope over C is the per-pass unit time;
  2. fused sweep: t_dense(C) / pass_time fit to fixed + per_col * C;
  3. sort: t_argsort / (pass_time * log2 n).

and writes a JSON profile ``planner.load_cost_profile()`` consumes —
replacing the hand-set constants with the crossover the hardware actually
exhibits (a CPU reference lowering and a real TPU disagree wildly about
the fused kernel's fixed cost; the profile lets the same model serve
both).

With ``--dist`` it also measures the DISTRIBUTED join crossover on a
fake-device child mesh: broadcast (all-gather the build side) vs
key-partitioned (route both sides) at a sweep of build sizes. The model
prices broadcast at n_build*(n-1) moved rows and partitioned at
(n_probe+n_build)*(n-1)/n * dist_route_factor; setting the two equal at
the MEASURED crossover build size B* gives

    dist_route_factor = B* * n / (n_probe + B*)

which is written into the profile so planner.choose_dist_join flips
strategies where this hardware actually flips.

With ``--exchange`` it measures the hash-Exchange ROUTING LAYOUT
crossover on the same fake-device child mesh: the partitioned join with
``exchange_impl`` forced to the stable argsort vs the radix-histogram
layout at a sweep of probe sizes. The model prices the argsort layout at
sort_pass_factor * log2(per-shard rows) pass-equivalents and the radix
layout flat; setting the two equal at the MEASURED crossover probe size
P* gives

    radix_route_factor = sort_pass_factor * log2(P* / devices)

written into the profile so planner.choose_exchange_impl flips layouts
where this hardware actually flips.

With ``--morsel`` it measures the serving scheduler's SPLIT-PROBE
crossover in-process (no mesh): a PK-FK join pipeline dispatched as one
whole-plan morsel vs split into per-pool probe morsels (build side
replicated per pool) at a sweep of probe sizes. Below the crossover the
per-morsel dispatch overhead loses to one fused dispatch; the first
probe size where splitting wins (geometric midpoint with its
single-winning neighbor) is written as ``morsel_split_rows`` — the
threshold ``planner.lower`` marks PJoin probe phases morsel-splittable
at, cache-keyed like the other fitted constants.

With ``--refresh PROFILE.json`` it instead runs the TELEMETRY loop: load
the profile, execute a representative recorded workload (a selective-
probe partitioned join on a fake-device mesh — the shape whose runtime
selectivity static costing cannot see), and rewrite the profile's
drifting entries from the observed stats via
``telemetry.refresh_profile`` (``dist_route_factor`` from observed vs
estimated moved rows, ``compact_margin`` from observed Compact
occupancy; ``dense_group_limit`` is never auto-refreshed). Entries
within the drift band are left untouched — refresh complements the
microbenchmark fits, it does not replace them.

With ``--sweep-groups`` it additionally sweeps the GROUP DOMAIN and fits
the two remaining hand-set constants:

  * ``dense_group_limit`` — the largest swept n_groups where the dense
    full-width fused layout still beats the range-partitioned one (the
    hand-set constant is a VMEM model; the sweep measures where the
    crossover actually sits on this backend);
  * ``partition_capacity_factor`` — the smallest capacity factor at which
    the range-partitioned layout reports ZERO overflow on a zipf-skewed
    key set (the paper's e=0.5 skew), times a 1.25 safety margin. The
    planner applies it to the partitioned AGGREGATE layout only; routing
    capacities stay on the ExecutionContext.

    PYTHONPATH=src python scripts/calibrate_costs.py --out cost_profile.json
    PYTHONPATH=src python scripts/calibrate_costs.py --dist --out cost_profile.json
    PYTHONPATH=src python scripts/calibrate_costs.py --exchange --out cost_profile.json
    PYTHONPATH=src python scripts/calibrate_costs.py --morsel --out cost_profile.json
    PYTHONPATH=src python scripts/calibrate_costs.py --sweep-groups --out cost_profile.json
    PYTHONPATH=src python scripts/calibrate_costs.py --refresh cost_profile.json
    >>> planner.load_cost_profile("cost_profile.json")
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

import numpy as np

def calibrate_dist(probe: int, builds, devices: int):
    """(dist_route_factor, raw sweep) from a fake-device child mesh.

    The child runs repro.analytics.dist_join_bench.sweep_code through
    benchmarks.common.run_in_mesh — the SAME snippet and the SAME
    subprocess harness benchmarks/fig7_index_join.py uses, so the fitted
    constant prices exactly what the benchmark (and the planner's cost
    model) measures."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (root, os.path.join(root, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    from benchmarks.common import run_in_mesh
    from repro.analytics.dist_join_bench import sweep_code
    raw = run_in_mesh(sweep_code(probe=probe, builds=builds,
                                 devices=devices),
                      n_devices=devices, timeout=1800)
    sweep = sorted((int(b), d) for b, d in raw.items())
    # crossover: first build size where routing both sides beats the
    # all-gather; geometric midpoint with its broadcast-winning neighbor
    b_star = None
    for i, (b, d) in enumerate(sweep):
        if d["partitioned"] < d["broadcast"]:
            b_star = (math.sqrt(sweep[i - 1][0] * b) if i else float(b))
            break
    if b_star is None:
        # partitioned never won in range: pin the factor just above the
        # largest measured build so the model keeps broadcasting there
        b_star = 2.0 * sweep[-1][0]
    factor = b_star * devices / (probe + b_star)
    return max(round(float(factor), 4), 0.01), raw


def calibrate_exchange(probes, build: int, devices: int,
                       sort_pass_factor: float):
    """(radix_route_factor, raw sweep) from the forced-impl Exchange
    sweep — repro.analytics.dist_join_bench.exchange_code, the SAME
    snippet fig7_index_join.run_dist records, through the same
    subprocess-mesh harness.

    choose_exchange_impl compares sort_pass_factor * log2(n) against the
    flat radix_route_factor at n = per-shard routed rows; equality at the
    measured crossover probe size P* fits the flat constant."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (root, os.path.join(root, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    from benchmarks.common import run_in_mesh
    from repro.analytics.dist_join_bench import exchange_code
    raw = run_in_mesh(exchange_code(build=build, probes=probes,
                                    devices=devices),
                      n_devices=devices, timeout=1800)
    sweep = sorted((int(p), d) for p, d in raw.items())
    # crossover: first probe size where the radix layout beats the
    # argsort; geometric midpoint with its argsort-winning neighbor
    p_star = None
    for i, (p, d) in enumerate(sweep):
        if d["radix"] < d["argsort"]:
            p_star = (math.sqrt(sweep[i - 1][0] * p) if i else float(p))
            break
    if p_star is None:
        # radix never won in range: pin the crossover just above the
        # largest measured probe so the model keeps the argsort layout
        p_star = 2.0 * sweep[-1][0]
    factor = sort_pass_factor * math.log2(max(p_star / devices, 2.0))
    return max(round(float(factor), 4), 0.01), raw


def calibrate_morsel(probes, n_pools: int, workers: int,
                     morsels_per_pool: int = 4):
    """(morsel_split_rows, raw sweep) from the in-process serving
    scheduler: single-morsel whole-plan dispatch vs split-probe dispatch
    of the SAME join pipeline, per probe size.

    Both sides run through MorselScheduler.run — the exact dispatch path
    build_task takes in production — with the split decision forced each
    way via the profile's morsel_split_rows (n+1 = never split, 1 =
    always split), so the fitted threshold prices exactly the overhead
    the planner's mark trades against."""
    import dataclasses

    import jax.numpy as jnp

    from repro.analytics import plan as L
    from repro.analytics import planner
    from repro.analytics.planner import ExecutionContext
    from repro.analytics.service.scheduler import MorselScheduler

    rng = np.random.RandomState(7)
    dim_rows = 256
    base = planner.current_cost_profile()
    raw = {}
    wins = []                          # (probe_rows, split_won) ascending
    try:
        for n in sorted(probes):
            tables = {
                "fact": {"fk": jnp.asarray(rng.randint(
                             0, dim_rows, n).astype(np.int32)),
                         "fv": jnp.asarray(rng.rand(n).astype(np.float32))},
                "dim": {"pk": jnp.asarray(np.arange(dim_rows,
                                                    dtype=np.int32)),
                        "dv": jnp.asarray(rng.rand(dim_rows).astype(
                            np.float32))},
            }
            p = L.LogicalPlan(
                L.scan("fact").join(L.scan("dim"), "fk", "pk", {"dv": "dv"})
                .aggregate("fk", dim_rows, s=("sum", "fv"),
                           c=("count", "fv")), None)
            ctx = ExecutionContext()
            morsel = max(n // (n_pools * morsels_per_pool), 1)
            t = {}
            for tag, threshold in (("single", n + 1), ("split", 1)):
                planner.set_cost_profile(dataclasses.replace(
                    base, morsel_split_rows=threshold))
                with MorselScheduler(n_pools=n_pools,
                                     workers_per_pool=workers,
                                     morsel_rows=morsel) as sched:
                    t[tag] = time_fn(lambda: sched.run(p, tables, ctx))
            raw[str(n)] = {k: round(v * 1e6, 1) for k, v in t.items()}
            wins.append((n, t["split"] < t["single"]))
    finally:
        planner.set_cost_profile(base)
    p_star = None
    for i, (n, won) in enumerate(wins):
        if won:
            p_star = (math.sqrt(wins[i - 1][0] * n) if i else float(n))
            break
    if p_star is None:
        # splitting never won in range: pin the threshold just above the
        # largest measured probe so the planner keeps whole-plan dispatch
        p_star = 2.0 * wins[-1][0]
    return max(int(round(p_star)), 1), raw


def sweep_groups(rows: int, groups_sweep, cols: int, mode,
                 capacity_factors) -> dict:
    """Measure the dense/partitioned crossover over n_groups and the
    smallest zero-overflow partition capacity factor under zipf skew.

    Returns {"dense_group_limit", "partition_capacity_factor", raw
    timings}. dense_group_limit falls back to the builtin constant when
    dense wins everywhere in range (the sweep then only certifies it)."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.analytics.columnar import (DENSE_GROUP_LIMIT,
                                          stacked_group_sums)
    from repro.analytics.datasets import zipf

    rng = np.random.RandomState(1)
    raw = {"dense": {}, "partitioned": {}}
    wins = []                      # (G, dense_won) in ascending-G order
    for G in sorted(groups_sweep):
        keys = jnp.asarray(rng.randint(0, G, rows).astype(np.int32))
        vals = jnp.asarray(rng.rand(rows, cols).astype(np.float32))
        t = {}
        for layout in ("dense", "partitioned"):
            fn = jax.jit(functools.partial(stacked_group_sums, n_groups=G,
                                           layout=layout, mode=mode))
            t[layout] = time_fn(lambda: fn(keys, vals))
            raw[layout][str(G)] = round(t[layout] * 1e6, 1)
        wins.append((G, t["dense"] <= t["partitioned"]))
    # Crossover = first SUSTAINED loss (a loss followed by another loss,
    # or a loss at the end of the range): a single noisy sample at either
    # end can neither disable dense everywhere nor extend it past the
    # measured flip. The fitted limit is the last win before it.
    cross_idx = next(
        (i for i, (_G, won) in enumerate(wins)
         if not won and (i == len(wins) - 1 or not wins[i + 1][1])), None)
    if cross_idx is None:
        # dense never sustainedly lost in range: no crossover observed,
        # keep the VMEM-model constant rather than extrapolate past data
        limit = DENSE_GROUP_LIMIT
    else:
        prior_wins = [G for G, won in wins[:cross_idx] if won]
        # no win below the crossover: the measurement upper-bounds the
        # limit just below the smallest swept point (recording the
        # permissive builtin would contradict the sweep's own numbers)
        limit = max(prior_wins) if prior_wins else min(groups_sweep) - 1

    # capacity-factor fit: smallest cf with zero overflow on zipf keys
    ds = zipf(rows, max(groups_sweep), seed=3)
    keys = jnp.asarray(ds.keys)
    vals = jnp.asarray(np.stack([ds.vals] * cols, axis=1))
    fitted_cf = None
    raw["overflow_at_cf"] = {}
    for cf in sorted(capacity_factors):
        fn = jax.jit(functools.partial(
            stacked_group_sums, n_groups=max(groups_sweep),
            layout="partitioned", mode=mode, capacity_factor=cf))
        _sums, ovf = jax.block_until_ready(fn(keys, vals))
        raw["overflow_at_cf"][str(cf)] = int(np.asarray(ovf))
        if int(np.asarray(ovf)) == 0:
            fitted_cf = cf
            break
    if fitted_cf is None:
        # every swept factor overflowed: the fit is INCONCLUSIVE — leave
        # the profile entry null (the planner keeps the context's factor)
        # rather than record a known-overflowing value as calibrated
        print(f"sweep_groups: no overflow-free capacity factor in "
              f"{sorted(capacity_factors)} (overflows: "
              f"{raw['overflow_at_cf']}); leaving "
              f"partition_capacity_factor unset", file=sys.stderr)
    return {
        "dense_group_limit": int(limit),
        "partition_capacity_factor": (None if fitted_cf is None
                                      else round(float(fitted_cf) * 1.25,
                                                 4)),
        "raw": raw,
    }


def refresh_from_telemetry(path: str, devices: int) -> None:
    """Rewrite ``path``'s drifting cost entries from observed telemetry.

    Must run before jax is imported anywhere in the process: it forces
    ``devices`` fake host devices so the recorded workload exercises the
    real distributed Exchange/Compact lowerings."""
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}").strip()
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.analytics import plan as L
    from repro.analytics import planner, telemetry

    from repro.core.config import PlacementPolicy

    profile = planner.load_cost_profile(path)
    rng = np.random.RandomState(0)
    n_rows = ((1 << 12) // devices) * devices
    dim_rows = 512
    tables = {
        "fact": {"fk": jnp.asarray(
                     rng.randint(0, dim_rows, n_rows).astype(np.int32)),
                 "fv": jnp.asarray(rng.rand(n_rows).astype(np.float32))},
        "dim": {"pk": jnp.asarray(np.arange(dim_rows, dtype=np.int32)),
                "dv": jnp.asarray(rng.rand(dim_rows).astype(np.float32))},
    }
    # selective probe ahead of a forced-partitioned join: the routed
    # traffic the profile's dist_route_factor prices, observed exactly
    p = L.LogicalPlan(
        L.scan("fact").filter(L.col("fv") < 0.1)
        .join(L.scan("dim"), "fk", "pk", {"dv": "dv"})
        .aggregate("fk", dim_rows, c=("count", "fv"), x=("max", "dv")),
        ("c", "x"))
    mesh = Mesh(np.array(jax.devices()[:devices]), ("data",))
    ctx = planner.ExecutionContext(executor="cost", mesh=mesh,
                                   policy=PlacementPolicy.INTERLEAVE,
                                   dist_join="partitioned")
    telemetry.registry().clear()
    with telemetry.recording():
        planner.compile_plan(p, tables, ctx)(tables)
    refreshed = telemetry.refresh_profile(profile)
    planner.set_cost_profile(None)
    if refreshed is profile:
        print(f"refresh: no cost entry drifted outside the "
              f"{telemetry.DRIFT_BAND}x band; {path} left unchanged")
        return
    with open(path) as f:
        raw = json.load(f)
    updates = {}
    for entry in ("dist_route_factor", "compact_margin",
                  "filter_selectivity"):
        new = getattr(refreshed, entry)
        if new is not None and new != getattr(profile, entry):
            updates[entry] = new
    raw.update(updates)
    raw["refreshed_from"] = "telemetry"
    with open(path, "w") as f:
        json.dump(raw, f, indent=2)
        f.write("\n")
    print(f"refresh: rewrote {sorted(updates)} in {path}: "
          + ", ".join(f"{k}={v}" for k, v in sorted(updates.items())))


def time_fn(fn, *, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call, results blocked."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1 << 18,
                    help="input rows for the microbenchmarks")
    ap.add_argument("--groups", type=int, default=512,
                    help="group domain (must stay under DENSE_GROUP_LIMIT)")
    ap.add_argument("--cols", type=int, nargs="+", default=[1, 2, 3, 4, 6],
                    help="stacked-matrix widths to sweep")
    ap.add_argument("--mode", default=None,
                    help="kernel lowering mode (None = backend default)")
    ap.add_argument("--dist", action="store_true",
                    help="also measure the broadcast vs partitioned "
                         "distributed-join crossover on a fake-device mesh "
                         "and fit dist_route_factor")
    ap.add_argument("--exchange", action="store_true",
                    help="also measure the argsort vs radix Exchange "
                         "routing-layout crossover on a fake-device mesh "
                         "and fit radix_route_factor")
    ap.add_argument("--exchange-probes", type=int, nargs="+",
                    default=[1 << b for b in range(10, 19, 2)],
                    help="probe sizes to sweep for the --exchange "
                         "crossover")
    ap.add_argument("--exchange-build", type=int, default=1 << 14,
                    help="build-side size for the --exchange sweep")
    ap.add_argument("--morsel", action="store_true",
                    help="also measure the serving scheduler's whole-plan "
                         "vs split-probe dispatch crossover in-process and "
                         "fit morsel_split_rows")
    ap.add_argument("--morsel-probes", type=int, nargs="+",
                    default=[1 << b for b in range(8, 17, 2)],
                    help="probe sizes to sweep for the --morsel crossover")
    ap.add_argument("--morsel-pools", type=int, default=2)
    ap.add_argument("--morsel-workers", type=int, default=2)
    ap.add_argument("--sweep-groups", action="store_true",
                    help="also sweep n_groups to fit dense_group_limit and "
                         "the partitioned-layout capacity factor")
    ap.add_argument("--groups-sweep", type=int, nargs="+",
                    default=[512, 1024, 2048, 4096, 8192, 16384],
                    help="group domains for the --sweep-groups crossover")
    ap.add_argument("--capacity-factors", type=float, nargs="+",
                    default=[1.0, 1.25, 1.5, 2.0, 3.0],
                    help="candidate partition capacity factors "
                         "(--sweep-groups fits the smallest overflow-free)")
    ap.add_argument("--refresh", metavar="PROFILE.json", default=None,
                    help="telemetry-refresh mode: run a recorded "
                         "representative workload on a fake-device mesh and "
                         "rewrite the profile's drifting entries "
                         "(dist_route_factor / compact_margin) from the "
                         "observed stats; all other sweeps are skipped")
    ap.add_argument("--dist-devices", type=int, default=8)
    ap.add_argument("--dist-probe", type=int, default=1 << 17,
                    help="probe rows for the distributed-join sweep")
    ap.add_argument("--dist-builds", type=int, nargs="+",
                    default=[1 << b for b in range(10, 18, 2)],
                    help="build-side sizes to sweep for the crossover")
    ap.add_argument("--out", default="cost_profile.json")
    args = ap.parse_args()

    if args.refresh:
        # must precede ANY jax import (it forces fake host devices)
        refresh_from_telemetry(args.refresh, min(args.dist_devices, 4))
        return

    import functools

    import jax
    import jax.numpy as jnp

    from repro.analytics.columnar import stacked_group_sums

    rng = np.random.RandomState(0)
    N, G = args.rows, args.groups
    keys = jnp.asarray(rng.randint(0, G, N).astype(np.int32))

    def bench(layout: str, C: int) -> float:
        vals = jnp.asarray(rng.rand(N, C).astype(np.float32))
        fn = jax.jit(functools.partial(stacked_group_sums, n_groups=G,
                                       layout=layout, mode=args.mode))
        return time_fn(lambda: fn(keys, vals))

    cols = sorted(set(args.cols))
    t_xla = {C: bench("xla", C) for C in cols}
    t_dense = {C: bench("dense", C) for C in cols}
    # per-pass unit time = slope of the one-segment-op-per-column layout
    xs = np.asarray(cols, np.float64)
    pass_time = max(float(np.polyfit(xs, [t_xla[C] for C in cols], 1)[0]),
                    1e-9)
    # fused pass-equivalents: fixed + per_col * C
    fused_eq = np.asarray([t_dense[C] / pass_time for C in cols])
    per_col, fixed = np.polyfit(xs, fused_eq, 1)
    # the model needs positive constants; a negative fit (e.g. a noisy
    # tiny-input run) falls back toward the hand-set shape
    fixed = max(float(fixed), 0.05)
    per_col = max(float(per_col), 0.01)

    t_sort = time_fn(lambda: jnp.sort(keys))
    sort_factor = max(t_sort / (pass_time * math.log2(max(N, 2))), 0.01)

    profile = {
        "fused_fixed": round(fixed, 4),
        "fused_per_col": round(per_col, 4),
        "sort_pass_factor": round(float(sort_factor), 4),
        "backend": jax.default_backend(),
        "n_rows": N,
        "n_groups": G,
        "pass_time_us": round(pass_time * 1e6, 3),
        "raw_us": {
            "xla": {str(C): round(t_xla[C] * 1e6, 1) for C in cols},
            "dense": {str(C): round(t_dense[C] * 1e6, 1) for C in cols},
            "sort": round(t_sort * 1e6, 1),
        },
    }
    if args.sweep_groups:
        fit = sweep_groups(args.rows, args.groups_sweep, max(cols),
                           args.mode, args.capacity_factors)
        profile["dense_group_limit"] = fit["dense_group_limit"]
        profile["partition_capacity_factor"] = \
            fit["partition_capacity_factor"]
        profile["raw_us"]["groups_sweep"] = fit["raw"]
    if args.dist:
        factor, raw_dist = calibrate_dist(args.dist_probe, args.dist_builds,
                                          args.dist_devices)
        profile["dist_route_factor"] = factor
        profile["dist_probe"] = args.dist_probe
        profile["dist_devices"] = args.dist_devices
        profile["raw_us"]["dist_join"] = raw_dist
    if args.exchange:
        # fit against the sort factor just measured above, so both sides
        # of the choose_exchange_impl comparison share one unit system
        factor, raw_ex = calibrate_exchange(
            args.exchange_probes, args.exchange_build, args.dist_devices,
            profile["sort_pass_factor"])
        profile["radix_route_factor"] = factor
        profile["exchange_build"] = args.exchange_build
        profile["raw_us"]["exchange_impl"] = raw_ex
    if args.morsel:
        threshold, raw_morsel = calibrate_morsel(
            args.morsel_probes, args.morsel_pools, args.morsel_workers)
        profile["morsel_split_rows"] = threshold
        profile["morsel_pools"] = args.morsel_pools
        profile["raw_us"]["morsel_split"] = raw_morsel

    with open(args.out, "w") as f:
        json.dump(profile, f, indent=2)
        f.write("\n")
    print(json.dumps(profile, indent=2))
    print(f"\nwrote {args.out}; install with "
          f"planner.load_cost_profile({args.out!r})")


if __name__ == "__main__":
    main()
