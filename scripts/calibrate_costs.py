"""Calibrate the planner's cost constants from measured microbenchmarks.

The cost model (planner.aggregate_costs) expresses every physical
Aggregate layout in *pass-equivalents* over the input rows, with three
hand-set constants: FUSED_FIXED (fused sweep setup), FUSED_PER_COL
(marginal cost per stacked column), SORT_PASS_FACTOR (argsort passes per
log2 n). This script measures them on the CURRENT backend:

  1. one-pass baseline: t_xla(C) — the XLA layout runs one segment op per
     stacked column, so its slope over C is the per-pass unit time;
  2. fused sweep: t_dense(C) / pass_time fit to fixed + per_col * C;
  3. sort: t_argsort / (pass_time * log2 n).

and writes a JSON profile ``planner.load_cost_profile()`` consumes —
replacing the hand-set constants with the crossover the hardware actually
exhibits (a CPU reference lowering and a real TPU disagree wildly about
the fused kernel's fixed cost; the profile lets the same model serve
both).

With ``--dist`` it also measures the DISTRIBUTED join crossover on a
fake-device child mesh: broadcast (all-gather the build side) vs
key-partitioned (route both sides) at a sweep of build sizes. The model
prices broadcast at n_build*(n-1) moved rows and partitioned at
(n_probe+n_build)*(n-1)/n * dist_route_factor; setting the two equal at
the MEASURED crossover build size B* gives

    dist_route_factor = B* * n / (n_probe + B*)

which is written into the profile so planner.choose_dist_join flips
strategies where this hardware actually flips.

    PYTHONPATH=src python scripts/calibrate_costs.py --out cost_profile.json
    PYTHONPATH=src python scripts/calibrate_costs.py --dist --out cost_profile.json
    >>> planner.load_cost_profile("cost_profile.json")
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

import numpy as np

def calibrate_dist(probe: int, builds, devices: int):
    """(dist_route_factor, raw sweep) from a fake-device child mesh.

    The child runs repro.analytics.dist_join_bench.sweep_code through
    benchmarks.common.run_in_mesh — the SAME snippet and the SAME
    subprocess harness benchmarks/fig7_index_join.py uses, so the fitted
    constant prices exactly what the benchmark (and the planner's cost
    model) measures."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (root, os.path.join(root, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    from benchmarks.common import run_in_mesh
    from repro.analytics.dist_join_bench import sweep_code
    raw = run_in_mesh(sweep_code(probe=probe, builds=builds,
                                 devices=devices),
                      n_devices=devices, timeout=1800)
    sweep = sorted((int(b), d) for b, d in raw.items())
    # crossover: first build size where routing both sides beats the
    # all-gather; geometric midpoint with its broadcast-winning neighbor
    b_star = None
    for i, (b, d) in enumerate(sweep):
        if d["partitioned"] < d["broadcast"]:
            b_star = (math.sqrt(sweep[i - 1][0] * b) if i else float(b))
            break
    if b_star is None:
        # partitioned never won in range: pin the factor just above the
        # largest measured build so the model keeps broadcasting there
        b_star = 2.0 * sweep[-1][0]
    factor = b_star * devices / (probe + b_star)
    return max(round(float(factor), 4), 0.01), raw


def time_fn(fn, *, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call, results blocked."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1 << 18,
                    help="input rows for the microbenchmarks")
    ap.add_argument("--groups", type=int, default=512,
                    help="group domain (must stay under DENSE_GROUP_LIMIT)")
    ap.add_argument("--cols", type=int, nargs="+", default=[1, 2, 3, 4, 6],
                    help="stacked-matrix widths to sweep")
    ap.add_argument("--mode", default=None,
                    help="kernel lowering mode (None = backend default)")
    ap.add_argument("--dist", action="store_true",
                    help="also measure the broadcast vs partitioned "
                         "distributed-join crossover on a fake-device mesh "
                         "and fit dist_route_factor")
    ap.add_argument("--dist-devices", type=int, default=8)
    ap.add_argument("--dist-probe", type=int, default=1 << 17,
                    help="probe rows for the distributed-join sweep")
    ap.add_argument("--dist-builds", type=int, nargs="+",
                    default=[1 << b for b in range(10, 18, 2)],
                    help="build-side sizes to sweep for the crossover")
    ap.add_argument("--out", default="cost_profile.json")
    args = ap.parse_args()

    import functools

    import jax
    import jax.numpy as jnp

    from repro.analytics.columnar import stacked_group_sums

    rng = np.random.RandomState(0)
    N, G = args.rows, args.groups
    keys = jnp.asarray(rng.randint(0, G, N).astype(np.int32))

    def bench(layout: str, C: int) -> float:
        vals = jnp.asarray(rng.rand(N, C).astype(np.float32))
        fn = jax.jit(functools.partial(stacked_group_sums, n_groups=G,
                                       layout=layout, mode=args.mode))
        return time_fn(lambda: fn(keys, vals))

    cols = sorted(set(args.cols))
    t_xla = {C: bench("xla", C) for C in cols}
    t_dense = {C: bench("dense", C) for C in cols}
    # per-pass unit time = slope of the one-segment-op-per-column layout
    xs = np.asarray(cols, np.float64)
    pass_time = max(float(np.polyfit(xs, [t_xla[C] for C in cols], 1)[0]),
                    1e-9)
    # fused pass-equivalents: fixed + per_col * C
    fused_eq = np.asarray([t_dense[C] / pass_time for C in cols])
    per_col, fixed = np.polyfit(xs, fused_eq, 1)
    # the model needs positive constants; a negative fit (e.g. a noisy
    # tiny-input run) falls back toward the hand-set shape
    fixed = max(float(fixed), 0.05)
    per_col = max(float(per_col), 0.01)

    t_sort = time_fn(lambda: jnp.sort(keys))
    sort_factor = max(t_sort / (pass_time * math.log2(max(N, 2))), 0.01)

    profile = {
        "fused_fixed": round(fixed, 4),
        "fused_per_col": round(per_col, 4),
        "sort_pass_factor": round(float(sort_factor), 4),
        "backend": jax.default_backend(),
        "n_rows": N,
        "n_groups": G,
        "pass_time_us": round(pass_time * 1e6, 3),
        "raw_us": {
            "xla": {str(C): round(t_xla[C] * 1e6, 1) for C in cols},
            "dense": {str(C): round(t_dense[C] * 1e6, 1) for C in cols},
            "sort": round(t_sort * 1e6, 1),
        },
    }
    if args.dist:
        factor, raw_dist = calibrate_dist(args.dist_probe, args.dist_builds,
                                          args.dist_devices)
        profile["dist_route_factor"] = factor
        profile["dist_probe"] = args.dist_probe
        profile["dist_devices"] = args.dist_devices
        profile["raw_us"]["dist_join"] = raw_dist

    with open(args.out, "w") as f:
        json.dump(profile, f, indent=2)
        f.write("\n")
    print(json.dumps(profile, indent=2))
    print(f"\nwrote {args.out}; install with "
          f"planner.load_cost_profile({args.out!r})")


if __name__ == "__main__":
    main()
