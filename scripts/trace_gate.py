#!/usr/bin/env python
"""CI gate for request-scoped tracing (ISSUE 8 acceptance).

Serves a traced multi-tenant chaos round (fig_service_faults style:
scheduled build failure + wait poison + mid-round pool kill, morsel-split
over two pools with stealing), plus one traced whole-plan compile+execute
for the plan-level spans, and exits non-zero if any contract is broken:

  1. the exported Chrome trace is valid JSON with >= 6 distinct phase
     names and populated pool/worker lanes (pid lanes beyond "service");
  2. no span is left open after the round (span conservation);
  3. every completed request's phase attribution (queue_wait/batch_wait/
     retry_backoff/execute/merge) sums to <= its wall latency, and
     ServiceStats reports a populated per-class p99 decomposition;
  4. every injected fault produced a NON-EMPTY flight-recorder dump;
  5. zero-cost-when-disabled: an identical untraced round allocates NO
     spans (``Tracer.created`` unchanged), and flipping the tracing flag
     does not change the plan-cache key (no re-jit).

The script configures its own fake host devices, so it must run as a
standalone process (scripts/ci.sh invokes it after drift_gate):

    PYTHONPATH=src python scripts/trace_gate.py
"""
import json
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4"
                           ).strip()
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(_ROOT, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_ROOT, "src"))


def main() -> int:
    from repro.analytics import planner, tracing
    from repro.analytics.service import (AnalyticsService, RetryPolicy,
                                         ServiceConfig,
                                         ServiceFaultInjector,
                                         ThreadPlacement)
    from repro.analytics.service.service import PHASES
    from repro.analytics.tpch import LOGICAL_QUERIES, generate, submit_query

    data = generate(scale=0.004, seed=1)
    tables = data.as_jax()
    ctx = planner.ExecutionContext(executor="xla")

    def config(faults=None):
        return ServiceConfig(
            n_pools=2, workers_per_pool=2, morsel_rows=997,
            placement=ThreadPlacement.SPARSE, faults=faults,
            retry=RetryPolicy(max_attempts=4, base_backoff_s=0.002,
                              max_backoff_s=0.02))

    def serve_round(faults=None):
        """Three waves of the five TPC-H plans across three tenants and
        two priority classes; waves advance dispatch ordinals past the
        fault schedule (identical requests dedup into ONE share)."""
        results, rids = {}, []
        with AnalyticsService(config(faults)) as svc:
            for _ in range(3):
                rids += [submit_query(svc, n, data, context=ctx,
                                      client_id=i % 3, priority=1 + i % 2)
                         for i, n in enumerate(LOGICAL_QUERIES)]
                results.update(svc.drain())
            st = svc.stats()
        return rids, results, st

    # -- 0. warm the plan cache untraced, then measure the traced round --
    planner.clear_plan_cache()
    serve_round()
    tracing.tracer().clear()

    faults = ServiceFaultInjector(seed=3, build_fail_at={6},
                                  poison_wait_at={8}, kill_pool_at=(11, 1))
    with tracing.tracing() as tr:
        rids, results, st = serve_round(faults)
        # whole-plan compile+execute for the plan-level spans (the
        # morsel-split service path never dispatches a whole CompiledPlan);
        # the cache is cleared so the compile is a genuine miss
        q6 = LOGICAL_QUERIES["q6"]
        planner.clear_plan_cache()
        planner.compile_plan(q6, tables, ctx)(tables)
        open_left = tr.open_spans()
        dumps = tr.flight.dumps()
        path = os.path.join(tempfile.mkdtemp(prefix="trace_gate_"),
                            "round.trace.json")
        tr.trace().save(path)
        trace = tr.trace()

    fired = (faults.builds_failed + faults.waits_poisoned
             + faults.pools_killed)
    if fired != 3:
        print(f"trace_gate: FAIL — expected all 3 scheduled faults to "
              f"fire, got {fired} (builds={faults.builds_failed} "
              f"poisons={faults.waits_poisoned} "
              f"kills={faults.pools_killed}); the wave structure no "
              "longer advances dispatch ordinals past the schedule")
        return 1

    # -- 1. chrome trace: valid JSON, >= 6 phases, pool lanes populated --
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", [])
    if not events:
        print("trace_gate: FAIL — exported Chrome trace has no events")
        return 1
    names = {e["name"] for e in events if e["ph"] in ("X", "i")}
    if len(names) < 6:
        print(f"trace_gate: FAIL — only {len(names)} distinct phase "
              f"names in the Chrome trace: {sorted(names)}")
        return 1
    pool_lanes = {p for p, _ in trace.lanes() if p.startswith("pool")}
    if not pool_lanes:
        print("trace_gate: FAIL — no pool/worker lanes in the trace "
              f"(lanes: {trace.lanes()})")
        return 1
    needed = {"queue.wait", "dispatch.build", "morsel.run",
              "merge.partials", "result.deliver", "retry.backoff",
              "plan.compile", "plan.execute"}
    missing = needed - names
    if missing:
        print(f"trace_gate: FAIL — serving-path phases missing from the "
              f"trace: {sorted(missing)}")
        return 1
    print(f"trace_gate: chrome trace OK ({len(events)} events, "
          f"{len(names)} phases, pool lanes {sorted(pool_lanes)}) "
          f"-> {path}")

    # -- 2. span conservation ------------------------------------------------
    if open_left:
        print(f"trace_gate: FAIL — {len(open_left)} spans left OPEN "
              f"after the round: "
              f"{[(o.name, o.trace_id) for o in open_left]}")
        return 1

    # -- 3. latency attribution ----------------------------------------------
    completed = [r for r in results.values() if r.value is not None]
    if not completed:
        print("trace_gate: FAIL — chaos round completed no requests")
        return 1
    for res in completed:
        if res.phases is None or set(res.phases) != set(PHASES):
            print(f"trace_gate: FAIL — request {res.req_id} missing "
                  f"phase attribution: {res.phases}")
            return 1
        total = sum(res.phases.values())
        if total > res.latency_s + 1e-6:
            print(f"trace_gate: FAIL — request {res.req_id} phase sum "
                  f"{total:.6f}s exceeds wall {res.latency_s:.6f}s: "
                  f"{res.phases}")
            return 1
    classes = [p for p, cs in st.per_class.items() if cs.phase_p99_ms]
    if not classes or st.phase_p99_ms.get("execute", 0.0) <= 0.0:
        print(f"trace_gate: FAIL — p99 decomposition not populated "
              f"(service={st.phase_p99_ms}, classes={classes})")
        return 1
    print(f"trace_gate: attribution OK ({len(completed)} completed; "
          f"p99 ms " + " ".join(f"{k}={st.phase_p99_ms[k]:.2f}"
                                for k in PHASES)
          + f"; classes {sorted(classes)})")

    # -- 4. flight recorder: one non-empty dump per injected fault ----------
    fault_dumps = [d for d in dumps if d.reason.startswith("fault.")]
    if len(fault_dumps) != fired:
        print(f"trace_gate: FAIL — {fired} faults fired but "
              f"{len(fault_dumps)} flight dumps recorded: "
              f"{[d.reason for d in dumps]}")
        return 1
    empty = [d.reason for d in fault_dumps if not d.spans]
    if empty:
        print(f"trace_gate: FAIL — EMPTY flight dumps for {empty}")
        return 1
    print(f"trace_gate: flight recorder OK "
          f"({[d.reason for d in fault_dumps]}, "
          f"{[len(d.spans) for d in fault_dumps]} spans)")

    # -- 5. zero-cost when disabled + cache-key stability --------------------
    before = tracing.tracer().created
    serve_round()
    after = tracing.tracer().created
    if after != before:
        print(f"trace_gate: FAIL — untraced round allocated "
              f"{after - before} spans; a hot-path hook is missing its "
              "tracing_enabled() guard")
        return 1
    off_key = planner.compile_plan(q6, tables, ctx).cache_key
    tracing.enable_tracing()
    try:
        h0 = planner.plan_cache_info().hits
        on = planner.compile_plan(q6, tables, ctx)
    finally:
        tracing.disable_tracing()
    if on.cache_key != off_key or planner.plan_cache_info().hits != h0 + 1:
        print("trace_gate: FAIL — tracing flag leaked into the "
              "plan-cache key (flipping it re-compiled the plan)")
        return 1
    print("trace_gate: zero-overhead OK (untraced round allocated 0 "
          "spans; tracing flag not in the plan-cache key)")

    # -- 6. split-probe morsel spans (ISSUE 10) ------------------------------
    # one traced q3 through a fresh service: the probe side splits into
    # per-pool morsels, and the trace must carry one morsel.run span PER
    # dispatched morsel, tied to the request, with the request's phase
    # attribution still summing to <= its wall latency (morsels overlap
    # across pools, so execute is wall-clock, not a per-morsel sum)
    tracing.tracer().clear()
    with tracing.tracing() as tr:
        with AnalyticsService(config()) as svc:
            rid = submit_query(svc, "q3", data, context=ctx)
            res3 = svc.drain()[rid]
            st3 = svc.stats()
        spans = tr.trace().spans
    morsel_spans = [s for s in spans
                    if s.name == "morsel.run" and s.trace_id == rid]
    if len(morsel_spans) < 2:
        print(f"trace_gate: FAIL — split-probe q3 produced only "
              f"{len(morsel_spans)} morsel.run spans (probe did not "
              "split, or spans lost their trace_id)")
        return 1
    if len(morsel_spans) != st3.morsels:
        print(f"trace_gate: FAIL — scheduler dispatched {st3.morsels} "
              f"morsels but the trace has {len(morsel_spans)} morsel.run "
              "spans (one span per split probe morsel)")
        return 1
    pools = {s.pid for s in morsel_spans}
    if res3.value is None or res3.phases is None:
        print(f"trace_gate: FAIL — traced split-probe request failed: "
              f"{res3.error}")
        return 1
    total3 = sum(res3.phases.values())
    if total3 > res3.latency_s + 1e-6:
        print(f"trace_gate: FAIL — split-probe request phase sum "
              f"{total3:.6f}s exceeds wall {res3.latency_s:.6f}s: "
              f"{res3.phases}")
        return 1
    print(f"trace_gate: split-probe spans OK ({len(morsel_spans)} "
          f"morsel.run spans across pools {sorted(pools)}; phase sum "
          f"{total3 * 1e3:.2f}ms <= wall {res3.latency_s * 1e3:.2f}ms)")
    print("trace_gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
