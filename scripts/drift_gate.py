#!/usr/bin/env python
"""CI gate for the telemetry -> cost-model feedback loop (ROADMAP item 3).

Runs the ISSUE-7 acceptance scenario end to end on a 4-fake-device mesh
and exits non-zero if any link of the loop is broken:

  1. a deliberately MIS-PRICED CostProfile (dist_route_factor 2x too
     high) makes the static cost model pick a broadcast join for a
     selective-probe query where partitioned is right;
  2. ONE telemetry-recorded execution produces a non-empty drift report
     (the probe filter keeps ~10% of rows — invisible to static costing);
  3. the next plan-cache HIT re-lowers with the observed alive rows and
     flips the Decision to partitioned, with results bit-identical to a
     fault-free run (only the lowering changed, never the answer);
  4. ``refresh_profile()`` pulls the mis-priced constant back: lowering
     fresh with the refreshed profile picks partitioned STATICALLY —
     the profile was corrected within one execution.

The script configures its own fake host devices, so it must run as a
standalone process (scripts/ci.sh invokes it after the test suite):

    PYTHONPATH=src python scripts/drift_gate.py
"""
import os
import sys

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4"
                           ).strip()
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(_ROOT, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np  # noqa: E402


def main() -> int:
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    import repro.analytics.physical as PH
    from repro.analytics import plan as L
    from repro.analytics import planner, telemetry
    from repro.core.config import PlacementPolicy

    rng = np.random.RandomState(7)
    n_rows, dim_rows = 768, 576
    tables = {
        "fact": {"fk": jnp.asarray(
                     rng.randint(0, dim_rows, n_rows).astype(np.int32)),
                 "fv": jnp.asarray(rng.rand(n_rows).astype(np.float32))},
        "dim": {"pk": jnp.asarray(np.arange(dim_rows, dtype=np.int32)),
                "dv": jnp.asarray(rng.rand(dim_rows).astype(np.float32))},
    }
    j = (L.scan("fact").filter(L.col("fv") < 0.1)
         .join(L.scan("dim"), "fk", "pk", {"dv": "dv"}))
    p = L.LogicalPlan(j.aggregate("fk", dim_rows, c=("count", "fv"),
                                  m=("median", "dv"), x=("max", "fv")),
                      ("c", "m", "x"))
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    ctx = planner.ExecutionContext(executor="cost", mesh=mesh,
                                   policy=PlacementPolicy.INTERLEAVE)

    planner.set_cost_profile(None)
    ref = planner.compile_plan(p, tables, ctx)(tables)

    mispriced = planner.CostProfile(dist_route_factor=3.0)
    planner.set_cost_profile(mispriced)
    telemetry.registry().clear()
    with telemetry.recording() as reg:
        cp1 = planner.compile_plan(p, tables, ctx)
        if "dist=broadcast" not in PH.describe(cp1.physical):
            print("drift_gate: FAIL — mis-priced profile did not pick "
                  "broadcast:\n" + PH.describe(cp1.physical))
            return 1
        cp1(tables)
        report = reg.drift_report()
        if not report:
            print("drift_gate: FAIL — one recorded execution produced an "
                  "EMPTY drift report")
            return 1
        print(f"drift_gate: drift report produced "
              f"({len(report)} drifting entries; worst: "
              f"{report[0]['node']} {report[0]['stat']} "
              f"obs={report[0]['observed']} est={report[0]['estimated']})")
        cp2 = planner.compile_plan(p, tables, ctx)   # cache HIT -> replan
        if "dist=partitioned" not in PH.describe(cp2.physical):
            print("drift_gate: FAIL — cache-hit replan did not flip to "
                  "partitioned:\n" + PH.describe(cp2.physical))
            return 1
        out = cp2(tables)
    for k in ("c", "m", "x"):
        if not np.array_equal(np.asarray(ref[k]), np.asarray(out[k]),
                              equal_nan=True):
            print(f"drift_gate: FAIL — replanned result {k!r} differs "
                  "from the fault-free run")
            return 1
    print(f"drift_gate: replan flipped broadcast -> partitioned on cache "
          f"hit (replans={reg.summary()['replans']}), results "
          "bit-identical to the fault-free run")

    refreshed = telemetry.refresh_profile(mispriced)
    planner.set_cost_profile(refreshed)
    try:
        fresh = planner.lower(p, ctx,
                              {t: len(next(iter(c.values())))
                               for t, c in tables.items()},
                              profile=refreshed, n_shards=4)
        if refreshed.dist_route_factor >= mispriced.dist_route_factor \
                or "dist=partitioned" not in PH.describe(fresh):
            print(f"drift_gate: FAIL — refresh_profile did not correct the "
                  f"mis-priced constant (factor "
                  f"{mispriced.dist_route_factor} -> "
                  f"{refreshed.dist_route_factor})")
            return 1
    finally:
        planner.set_cost_profile(None)
    print(f"drift_gate: profile corrected within one execution "
          f"(dist_route_factor {mispriced.dist_route_factor} -> "
          f"{refreshed.dist_route_factor}, source={refreshed.source!r})")
    print("drift_gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
