#!/usr/bin/env bash
# CI entry point: tier-1 tests, then the benchmark perf gates.
#
#   scripts/ci.sh [BASELINE.json]
#
# 1. runs the tier-1 pytest suite (ROADMAP "Tier-1 verify");
# 2. runs benchmarks/run.py over the in-process figures, recording rows to
#    a fresh JSON; when BASELINE.json exists the guarded rows present in
#    this selection (the tuned-Q1 latency gate) are checked against it and
#    a >25% regression fails the script. A missing baseline is recorded
#    instead of checked (first run bootstraps it).
#
# The subprocess-mesh figures (fig5, fig7_dist, fig_service) are skipped
# here for runtime — which means the served-QPS floor and the
# broadcast-vs-partitioned join rows are NOT gated by this script; run
# `python benchmarks/run.py --json ... --check ...` without --skip-slow
# for the full grid including those gates.
#
# The degraded-mode serving gate (fig_service_faults) runs IN-PROCESS and
# is therefore part of this sweep: run.py checks its
# fig_service_degraded_qps_ratio row against an ABSOLUTE floor (degraded
# QPS >= 50% of healthy after losing a worker pool mid-run) on every run
# that collects it — including the bootstrap run that has no baseline
# JSON yet. A pool loss that halves serving capacity fails CI here.
set -euo pipefail
cd "$(dirname "$0")/.."

BASELINE="${1:-bench_baseline.json}"
# "." puts the repo root on the path so `from benchmarks import ...`
# resolves when run.py is invoked as a script (sys.path[0] is benchmarks/)
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

# named gate: the physical-plan golden snapshots (explain_physical must
# stay string-stable; a drift here means the lowering/rewrites changed —
# fail fast with a readable tree diff before the full suite runs)
python -m pytest -x -q tests/test_explain_golden.py

# named gate: radix-kernel digit parity — ref and Pallas(interpret) must
# bin NEGATIVE keys (incl. the engine's -1 routed-padding sentinel)
# identically at every shift, and the block-padded histogram must match
# the unpadded oracle bit-exactly; the radix Exchange routing layout is
# built on both properties, so a drift here corrupts routed buffers
# before any parity suite would localize it
python -m pytest -x -q tests/test_kernels_analytics.py \
    -k "negative_key or padded_bin_counts"

# named gate: morsel parity — split-probe dispatch (build sides
# pool-replicated, probe morsels merged in morsel order) must stay
# BIT-IDENTICAL to the serial executor across the ThreadPlacement x
# PlacementPolicy grid, the build must materialize once per pool (never
# per morsel), and the distributed-TopK candidates lowering must move
# <= k x n_shards rows while matching the replicated lowering bit-exactly
python -m pytest -x -q tests/test_morsel_probe.py

python -m pytest -x -q

# named gate: the telemetry feedback loop — a deliberately mis-priced
# cost profile must (1) produce a drift report after ONE recorded
# execution, (2) flip its broadcast-join Decision to partitioned on the
# next plan-cache hit with bit-identical results, and (3) be corrected by
# refresh_profile. The script configures its own 4 fake host devices.
python scripts/drift_gate.py

# named gate: request-scoped tracing — a traced chaos round must export
# a valid Chrome trace (>= 6 phases, pool/worker lanes), leave no span
# open, keep every request's phase attribution <= its wall latency with
# one non-empty flight-recorder dump per injected fault, and an untraced
# round must allocate ZERO spans (the tracing flag stays out of the
# plan-cache key) — plus one morsel.run span per split probe morsel on a
# traced split-probe request. Configures its own 4 fake host devices.
python scripts/trace_gate.py

if [ -f "$BASELINE" ]; then
    python benchmarks/run.py --skip-slow --json BENCH_ci.json --check "$BASELINE"
else
    echo "ci.sh: no baseline at $BASELINE — recording one" >&2
    python benchmarks/run.py --skip-slow --json "$BASELINE"
fi
