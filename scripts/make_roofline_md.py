"""Generate the EXPERIMENTS.md §Roofline table from dry-run reports."""
import glob
import json
import sys

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def fmt(r):
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — "
                f"| — | — | skipped (full attention; DESIGN §8) |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — "
                f"| — | — | ERROR |")
    rf = r["roofline"]
    note = []
    if not r["fits_16gb"]:
        note.append(f"{r['bytes_per_device']/1e9:.0f}GB/dev > 16GB")
    return ("| {arch} | {shape} | {mesh} | {c:.3f} | {m:.3f} | {x:.3f} "
            "| **{b}** | {mfu:.3f} | {u:.2f} | {note} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        c=rf["compute_s"], m=rf["memory_s"], x=rf["collective_s"],
        b=rf["bottleneck"][:4], mfu=rf["mfu_bound"] or 0,
        u=r["useful_flops_ratio"] or 0, note="; ".join(note) or "fits")


def main(variant_filter=None):
    reports = []
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(f))
        stem = f.split("/")[-1][:-5]
        base = f"{r['arch']}_{r['shape']}_" + (
            "multi" if r["mesh"] == "2x16x16" else "single")
        r["_variant"] = stem[len(base):].lstrip("_") or "baseline"
        reports.append(r)
    reports.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9),
                                r["mesh"], r["_variant"]))
    print("| arch | shape | mesh | compute_s | memory_s | collective_s "
          "| bound | MFU bound | useful | memory note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in reports:
        if variant_filter == "baseline" and r["_variant"] != "baseline":
            continue
        if variant_filter == "variants" and r["_variant"] == "baseline":
            continue
        line = fmt(r)
        if variant_filter == "variants":
            line = line.replace(f"| {r['arch']} |",
                                f"| {r['arch']} ({r['_variant']}) |", 1)
        print(line)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
